//! Bounded LRU over **noise-free** joint train/test factorizations — the
//! predict-path twin of `train::cache::FactorCache`.
//!
//! `MkaGp::predict` is transductive: every batch factorizes the joint
//! (n+p)² train/test gram (§4.1), which makes the factorization — MKA's
//! one expensive step — a *per-request* cost under serving traffic. But
//! the joint factor is a pure function of (training set, kernel
//! hyperparameters, MKA config, test set): dashboards, grids and
//! replayed queries re-ask the same test set against the same model, so
//! the factor can be built once and served many times. This module keys
//! that reuse on
//!
//! * a caller-supplied **scope** — the model fingerprint: training-set
//!   identity (n, dim, data-bit hash), kernel hyperparameter bits
//!   ([`crate::kernels::Kernel::fingerprint`]) and the MKA config scope
//!   (the `train::mll::mka_scope` idiom) — and
//! * the **test-set fingerprint** — shape plus an FNV-1a hash over the
//!   exact f64 bit patterns of `x_test`.
//!
//! σ² is deliberately **absent** from the key: entries hold the
//! noise-free factor (shift 0) and consumers take
//! [`crate::mka::MkaFactor::shifted`] at the point of use, so a σ²-only
//! `retune` republish keeps every entry hot (exact under the default
//! shift-invariant pivot rules — see `mka::factor` for the SPCA /
//! MaxCorrelation caveat, the same scoping as the train-side cache).
//!
//! Determinism: a 64-bit hash can collide, and serving the wrong factor
//! would violate the bit-determinism contract silently — so every entry
//! stores its full `x_test` and a lookup only hits when the stored bits
//! match the query **exactly**. A hit therefore returns precisely what a
//! rebuild would produce (entries are bit-deterministic functions of
//! their key, fixed seeds all the way down), and cache-hit predictions
//! are bitwise identical to the cold path. Racing builders follow the
//! train-cache protocol: build outside the lock, first insert wins, the
//! duplicate (bit-identical) build is dropped.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::Result;
use crate::la::dense::Mat;
use crate::mka::MkaFactor;

/// Process-wide traffic gauges, surfaced by the coordinator's `metrics`
/// op as `compute.predict_cache_{hits,misses,evictions}`.
static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);
static EVICTIONS: AtomicU64 = AtomicU64::new(0);

/// Total predict-cache hits (joint factorizations *not* re-run) across
/// every model in this process.
pub fn predict_cache_hits() -> u64 {
    HITS.load(Ordering::Relaxed)
}

/// Total predict-cache misses (joint factorizations built) in this
/// process.
pub fn predict_cache_misses() -> u64 {
    MISSES.load(Ordering::Relaxed)
}

/// Total entries displaced by the LRU bound (capacity pressure, not
/// invalidation) in this process.
pub fn predict_cache_evictions() -> u64 {
    EVICTIONS.load(Ordering::Relaxed)
}

/// Default per-model capacity; `ServiceConfig.predict_cache_entries`
/// overrides it at router construction (0 disables caching). Same
/// process-wide last-writer-wins pattern as
/// `train::cache::set_default_capacity`.
static DEFAULT_CAPACITY: AtomicUsize = AtomicUsize::new(8);

/// Set the process-wide default capacity new caches are created with.
pub fn set_default_capacity(cap: usize) {
    DEFAULT_CAPACITY.store(cap, Ordering::Relaxed);
}

/// The current process-wide default capacity.
pub fn default_capacity() -> usize {
    DEFAULT_CAPACITY.load(Ordering::Relaxed)
}

/// FNV-1a over a stream of u64 words — deterministic, allocation-free,
/// and stable across platforms (explicit wrapping arithmetic).
fn fnv1a_words(words: impl Iterator<Item = u64>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for w in words {
        for shift in [0u32, 8, 16, 24, 32, 40, 48, 56] {
            h ^= (w >> shift) & 0xff;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// The test-set fingerprint: shape plus the FNV-1a hash of the exact
/// f64 bit patterns. Collisions are possible (64-bit hash) and are
/// handled by the stored-matrix equality check on lookup.
pub fn mat_fingerprint(m: &Mat) -> [u64; 3] {
    [m.rows as u64, m.cols as u64, fnv1a_words(m.data.iter().map(|v| v.to_bits()))]
}

/// FNV-1a hash of a training set's exact bits — one word of the model
/// fingerprint (scope), so two models over different data can never
/// share an entry even if a cache instance were shared between them.
pub fn data_fingerprint(x: &Mat, y: &[f64]) -> u64 {
    fnv1a_words(
        x.data
            .iter()
            .map(|v| v.to_bits())
            .chain(y.iter().map(|v| v.to_bits())),
    )
}

/// Exact bitwise equality of two matrices (shape + every f64 bit
/// pattern). Plain `==` is not enough: it treats `-0.0 == 0.0` and
/// `NaN != NaN`, either of which would let a hit diverge from the bits
/// the cold path serves.
fn bits_equal(a: &Mat, b: &Mat) -> bool {
    a.rows == b.rows
        && a.cols == b.cols
        && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// One cached joint factorization: the **noise-free** joint factor
/// (shift 0 — consumers take `shifted(σ²)`), the n×p `K_*` block the
/// mean formula needs, and the exact test matrix the entry was built
/// for (the collision guard).
pub struct JointEntry {
    /// The test inputs this entry answers — compared bit-for-bit on
    /// every lookup.
    pub x_test: Mat,
    /// Noise-free joint factorization of [[K, K_*], [K_*ᵀ, K_test]].
    pub factor: MkaFactor,
    /// The n×p train×test covariance block.
    pub kstar: Mat,
}

struct Slot {
    key: Vec<u64>,
    entry: Arc<JointEntry>,
    tick: u64,
}

#[derive(Default)]
struct Store {
    slots: Vec<Slot>,
    tick: u64,
}

/// A bounded LRU of [`JointEntry`]s. One instance per logical model:
/// `MkaGp::retuned` shares the instance (`Arc`) so σ²-only republishes
/// keep entries hot, while `observed`/refit/refresh paths build a fresh
/// instance — the training set changed, so every held entry is stale.
pub struct PredictCache {
    cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    store: Mutex<Store>,
}

impl PredictCache {
    /// A cache holding at most `cap` entries. `cap = 0` disables
    /// storage: every lookup builds, nothing is kept — but builds still
    /// count as instance misses so hit-rate reporting stays truthful.
    /// The process-wide gauges skip disabled caches.
    pub fn new(cap: usize) -> PredictCache {
        PredictCache {
            cap,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            store: Mutex::new(Store::default()),
        }
    }

    /// A cache sized by the service-configurable process default.
    pub fn with_default_capacity() -> PredictCache {
        PredictCache::new(default_capacity())
    }

    /// A cache that never stores anything.
    pub fn disabled() -> PredictCache {
        PredictCache::new(0)
    }

    /// Capacity this instance was created with.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.store.lock().unwrap().slots.len()
    }

    /// True when no entries are held.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hits observed by this instance (pollution-free, unlike the
    /// process gauges).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Misses (= joint factorizations built) through this instance.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries this instance displaced under capacity pressure.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// The joint entry for (`scope`, `x_test`), building it with
    /// `build` on a miss. Returns the entry plus whether this lookup
    /// was a hit. `scope` must encode everything besides the test set
    /// that determines the factor (the model fingerprint); a hit
    /// additionally requires the stored test matrix to equal `x_test`
    /// bit-for-bit — a fingerprint collision is served as a miss, never
    /// as the wrong factor.
    pub fn get_or_build(
        &self,
        scope: &[u64],
        x_test: &Mat,
        build: impl FnOnce() -> Result<JointEntry>,
    ) -> Result<(Arc<JointEntry>, bool)> {
        if self.cap == 0 {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return build().map(|e| (Arc::new(e), false));
        }
        let key = key_bits(scope, x_test);
        {
            let mut s = self.store.lock().unwrap();
            s.tick += 1;
            let tick = s.tick;
            if let Some(slot) = s.slots.iter_mut().find(|sl| sl.key == key) {
                if bits_equal(&slot.entry.x_test, x_test) {
                    slot.tick = tick;
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    HITS.fetch_add(1, Ordering::Relaxed);
                    return Ok((Arc::clone(&slot.entry), true));
                }
                // Fingerprint collision: same key, different test bits.
                // Fall through to a build; the insert below replaces the
                // colliding slot (lookups always verify bits, so the
                // replaced entry could never have answered this query).
            }
        }
        // Build OUTSIDE the lock: concurrent predicts against other test
        // sets must not serialize on this factorization. A failed build
        // is not cached — the error propagates and a later lookup
        // retries.
        self.misses.fetch_add(1, Ordering::Relaxed);
        MISSES.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(build()?);
        let mut s = self.store.lock().unwrap();
        s.tick += 1;
        let tick = s.tick;
        if let Some(slot) = s.slots.iter_mut().find(|sl| sl.key == key) {
            if bits_equal(&slot.entry.x_test, x_test) {
                // Another thread built the same (bit-identical) entry
                // first; keep the stored one and drop the duplicate.
                slot.tick = tick;
                return Ok((Arc::clone(&slot.entry), false));
            }
            // Collision slot: replace it (counted as an eviction — the
            // old entry is displaced, not invalid).
            self.evictions.fetch_add(1, Ordering::Relaxed);
            EVICTIONS.fetch_add(1, Ordering::Relaxed);
            slot.entry = Arc::clone(&built);
            slot.tick = tick;
            return Ok((built, false));
        }
        if s.slots.len() >= self.cap {
            let lru = s
                .slots
                .iter()
                .enumerate()
                .min_by_key(|(_, sl)| sl.tick)
                .map(|(i, _)| i)
                .expect("non-empty at capacity");
            crate::obs::log!(
                Warn,
                "gp.predict_cache",
                { "capacity" => self.cap },
                "predict cache full: displacing LRU joint factor — a repeat of its test set refactorizes"
            );
            self.evictions.fetch_add(1, Ordering::Relaxed);
            EVICTIONS.fetch_add(1, Ordering::Relaxed);
            s.slots.remove(lru);
        }
        s.slots.push(Slot { key, entry: Arc::clone(&built), tick });
        Ok((built, false))
    }

    /// Drop every entry whose key starts with `prefix`, returning how
    /// many were removed — the PR-9 `FactorCache::invalidate_scope`
    /// pattern. Keys are `[scope…, test fingerprint…]`, so a prefix of
    /// the model fingerprint evicts exactly that model's entries; an
    /// empty prefix clears the cache. Entries still borrowed through an
    /// `Arc` stay alive until the borrower drops them; they are only
    /// unreachable for future lookups.
    pub fn invalidate_scope(&self, prefix: &[u64]) -> usize {
        let mut s = self.store.lock().unwrap();
        let before = s.slots.len();
        s.slots.retain(|sl| !sl.key.starts_with(prefix));
        before - s.slots.len()
    }
}

fn key_bits(scope: &[u64], x_test: &Mat) -> Vec<u64> {
    let fp = mat_fingerprint(x_test);
    scope.iter().copied().chain(fp.iter().copied()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(v: f64, x_test: &Mat) -> JointEntry {
        JointEntry {
            x_test: x_test.clone(),
            factor: MkaFactor::new(1, vec![], Mat::from_rows(&[&[v]])),
            kstar: Mat::zeros(1, x_test.rows),
        }
    }

    fn xt(v: f64) -> Mat {
        Mat::from_rows(&[&[v]])
    }

    #[test]
    fn hit_and_miss_accounting() {
        let c = PredictCache::new(4);
        let x = xt(1.0);
        let (a, hit) = c.get_or_build(&[7], &x, || Ok(entry(1.0, &x))).unwrap();
        assert!(!hit);
        assert_eq!((c.hits(), c.misses()), (0, 1));
        let (b, hit) = c.get_or_build(&[7], &x, || panic!("must not rebuild on a hit")).unwrap();
        assert!(hit);
        assert_eq!((c.hits(), c.misses()), (1, 1));
        assert!(Arc::ptr_eq(&a, &b), "hit must return the stored entry");
        // a different test set is a different key
        let x2 = xt(2.0);
        let (_, hit) = c.get_or_build(&[7], &x2, || Ok(entry(2.0, &x2))).unwrap();
        assert!(!hit);
        assert_eq!((c.hits(), c.misses()), (1, 2));
    }

    #[test]
    fn scope_isolates_entries() {
        let c = PredictCache::new(4);
        let x = xt(1.0);
        let _ = c.get_or_build(&[1, 5], &x, || Ok(entry(1.0, &x))).unwrap();
        let mut rebuilt = false;
        let _ = c
            .get_or_build(&[2, 5], &x, || {
                rebuilt = true;
                Ok(entry(2.0, &x))
            })
            .unwrap();
        assert!(rebuilt, "same test set, different scope must not collide");
        let (_, hit) = c.get_or_build(&[1, 5], &x, || panic!("scoped hit expected")).unwrap();
        assert!(hit);
    }

    #[test]
    fn signed_zero_and_shape_are_part_of_the_identity() {
        let c = PredictCache::new(4);
        let pos = xt(0.0);
        let neg = xt(-0.0);
        let _ = c.get_or_build(&[], &pos, || Ok(entry(1.0, &pos))).unwrap();
        // -0.0 == 0.0 numerically, but the bits differ: must be a miss.
        let (_, hit) = c.get_or_build(&[], &neg, || Ok(entry(2.0, &neg))).unwrap();
        assert!(!hit, "-0.0 must not hit a 0.0 entry");
        // 1×2 and 2×1 with the same data bits are different test sets.
        let wide = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        let tall = Mat::from_vec(2, 1, vec![3.0, 4.0]);
        let _ = c.get_or_build(&[], &wide, || Ok(entry(3.0, &wide))).unwrap();
        let (_, hit) = c.get_or_build(&[], &tall, || Ok(entry(4.0, &tall))).unwrap();
        assert!(!hit, "shape is part of the fingerprint");
    }

    #[test]
    fn lru_evicts_least_recently_used_and_counts() {
        let c = PredictCache::new(2);
        let (x1, x2, x3) = (xt(1.0), xt(2.0), xt(3.0));
        let _ = c.get_or_build(&[], &x1, || Ok(entry(1.0, &x1))).unwrap();
        let _ = c.get_or_build(&[], &x2, || Ok(entry(2.0, &x2))).unwrap();
        assert_eq!(c.evictions(), 0);
        // touch x1 so x2 becomes LRU, then insert a third
        let _ = c.get_or_build(&[], &x1, || panic!("hit")).unwrap();
        let _ = c.get_or_build(&[], &x3, || Ok(entry(3.0, &x3))).unwrap();
        assert_eq!(c.evictions(), 1, "one displacement at capacity");
        assert_eq!(c.len(), 2);
        let _ = c.get_or_build(&[], &x1, || panic!("x1 must still be cached")).unwrap();
        let mut rebuilt = false;
        let _ = c
            .get_or_build(&[], &x2, || {
                rebuilt = true;
                Ok(entry(2.0, &x2))
            })
            .unwrap();
        assert!(rebuilt, "x2 must have been evicted");
    }

    #[test]
    fn disabled_cache_always_builds_and_counts_misses() {
        let c = PredictCache::disabled();
        let x = xt(1.0);
        let mut builds = 0;
        for _ in 0..3 {
            let (_, hit) = c
                .get_or_build(&[], &x, || {
                    builds += 1;
                    Ok(entry(1.0, &x))
                })
                .unwrap();
            assert!(!hit);
        }
        assert_eq!(builds, 3);
        assert_eq!((c.hits(), c.misses(), c.len()), (0, 3, 0));
    }

    #[test]
    fn build_errors_are_not_cached() {
        let c = PredictCache::new(2);
        let x = xt(1.0);
        let err = c.get_or_build(&[], &x, || Err(crate::error::Error::Linalg("boom".into())));
        assert!(err.is_err());
        let ok = c.get_or_build(&[], &x, || Ok(entry(1.0, &x)));
        assert!(ok.is_ok());
        assert_eq!(c.misses(), 2);
    }

    #[test]
    fn invalidate_scope_evicts_only_the_prefix() {
        let c = PredictCache::new(8);
        let (xa, xb) = (xt(1.0), xt(2.0));
        let _ = c.get_or_build(&[1, 9], &xa, || Ok(entry(1.0, &xa))).unwrap();
        let _ = c.get_or_build(&[1, 9], &xb, || Ok(entry(2.0, &xb))).unwrap();
        let _ = c.get_or_build(&[2, 9], &xa, || Ok(entry(3.0, &xa))).unwrap();
        assert_eq!(c.invalidate_scope(&[1]), 2);
        // scope 2 still hits…
        let (_, hit) = c.get_or_build(&[2, 9], &xa, || panic!("scope 2 untouched")).unwrap();
        assert!(hit);
        // …scope 1 rebuilds
        let mut rebuilt = false;
        let _ = c
            .get_or_build(&[1, 9], &xa, || {
                rebuilt = true;
                Ok(entry(1.0, &xa))
            })
            .unwrap();
        assert!(rebuilt);
        assert_eq!(c.invalidate_scope(&[99]), 0);
        assert!(c.invalidate_scope(&[]) >= 2);
        assert!(c.is_empty());
    }

    #[test]
    fn fingerprints_are_deterministic_and_shape_sensitive() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(mat_fingerprint(&a), mat_fingerprint(&b));
        let wide = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        assert_ne!(mat_fingerprint(&a), mat_fingerprint(&wide));
        assert_ne!(
            data_fingerprint(&a, &[1.0]),
            data_fingerprint(&a, &[2.0]),
            "targets are part of the training identity"
        );
    }
}
