//! Error measures from the paper (§5):
//!
//! * **SMSE** — standardized mean squared error:
//!   (1/n) Σ (ŷ_t − y_t)² / σ̂²_⋆ with σ̂²_⋆ the variance of the test
//!   outputs. A constant mean predictor scores ≈ 1.
//! * **MNLP** — mean negative log probability:
//!   (1/n) Σ ((ŷ_t − y_t)²/σ̂²_t + log σ̂²_t + log 2π), using each method's
//!   own predictive variance σ̂²_t (we follow the paper's printed formula,
//!   i.e. without the usual ½ factor — comparisons between methods are
//!   unaffected).

use crate::la::stats::variance;

/// Standardized mean squared error.
pub fn smse(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    assert!(!y_true.is_empty());
    let var_star = variance(y_true).max(1e-12);
    let mse = y_true
        .iter()
        .zip(y_pred)
        .map(|(t, p)| (t - p) * (t - p))
        .sum::<f64>()
        / y_true.len() as f64;
    mse / var_star
}

/// Mean negative log probability with per-point predictive variances.
pub fn mnlp(y_true: &[f64], y_pred: &[f64], var_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    assert_eq!(y_true.len(), var_pred.len());
    assert!(!y_true.is_empty());
    let ln2pi = (2.0 * std::f64::consts::PI).ln();
    y_true
        .iter()
        .zip(y_pred)
        .zip(var_pred)
        .map(|((t, p), v)| {
            let v = v.max(1e-12);
            (t - p) * (t - p) / v + v.ln() + ln2pi
        })
        .sum::<f64>()
        / y_true.len() as f64
}

/// Plain MSE (diagnostics).
pub fn mse(y_true: &[f64], y_pred: &[f64]) -> f64 {
    y_true
        .iter()
        .zip(y_pred)
        .map(|(t, p)| (t - p) * (t - p))
        .sum::<f64>()
        / y_true.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction_zero_smse() {
        let y = [1.0, 2.0, 3.0, -1.0];
        assert_eq!(smse(&y, &y), 0.0);
    }

    #[test]
    fn mean_predictor_smse_near_one() {
        let y = [1.0, 2.0, 3.0, 4.0];
        let mean = [2.5; 4];
        assert!((smse(&y, &mean) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mnlp_penalizes_overconfidence() {
        let y = [0.0];
        let pred = [1.0]; // error of 1
        let confident = mnlp(&y, &pred, &[0.01]);
        let calibrated = mnlp(&y, &pred, &[1.0]);
        assert!(confident > calibrated);
    }

    #[test]
    fn mnlp_of_exact_standard_normal() {
        // error 0, var 1 → ln 2π per point (paper formula, no ½).
        let v = mnlp(&[0.0], &[0.0], &[1.0]);
        assert!((v - (2.0 * std::f64::consts::PI).ln()).abs() < 1e-12);
    }

    #[test]
    fn mnlp_variance_floor() {
        // zero variance must not produce NaN/inf
        let v = mnlp(&[0.0], &[0.0], &[0.0]);
        assert!(v.is_finite());
    }

    #[test]
    fn mse_simple() {
        assert_eq!(mse(&[0.0, 0.0], &[1.0, -1.0]), 1.0);
    }
}
