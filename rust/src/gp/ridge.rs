//! Kernel ridge regression via an MKA solve — "MKA Ridge Regression"
//! (paper §4.1 title). The frequentist twin of the GP mean: the most
//! direct use of MKA, approximating K′ = K + λI itself and solving
//! α̃ = K̃′⁻¹ y (mean only, no predictive variance).
//!
//! As the paper notes, mixing the approximate inverse with exact k_x
//! introduces a small systematic bias relative to [`super::mka_gp::MkaGp`];
//! we keep both so the bias is measurable (see the ablation bench).

use super::{GpModel, Prediction};
use crate::data::dataset::Dataset;
use crate::error::Result;
use crate::kernels::Kernel;
use crate::la::blas::dot;
use crate::la::dense::Mat;
use crate::mka::{factorize, MkaConfig};

/// Ridge regressor with an MKA-approximated kernel solve.
pub struct MkaRidge {
    x_train: Mat,
    kernel: Box<dyn Kernel>,
    lambda: f64,
    /// α̃ = K̃′⁻¹ y, computed once at fit time ("direct method").
    alpha: Vec<f64>,
}

impl MkaRidge {
    pub fn fit(
        train: &Dataset,
        kernel: &dyn Kernel,
        lambda: f64,
        config: &MkaConfig,
    ) -> Result<MkaRidge> {
        // λ enters as a spectrum shift of the noise-free factorization
        // (exactly equivalent to factorizing K + λI — see `mka::factor`),
        // so ridge refits across regularization levels could share one
        // factorization the same way the training plane's cache does.
        let k = kernel.gram_sym(&train.x);
        let f = factorize(&k, Some(&train.x), config)?.shifted(lambda);
        let alpha = f.solve(&train.y)?;
        Ok(MkaRidge {
            x_train: train.x.clone(),
            kernel: kernel.boxed_clone(),
            lambda,
            alpha,
        })
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }
}

impl GpModel for MkaRidge {
    fn predict(&self, x_test: &Mat) -> Prediction {
        let mean: Vec<f64> = (0..x_test.rows)
            .map(|t| {
                let kx = self.kernel.cross(x_test.row(t), &self.x_train);
                dot(&kx, &self.alpha)
            })
            .collect();
        // Ridge regression has no predictive variance; report λ as a
        // homoscedastic placeholder so MNLP stays defined.
        let var = vec![self.lambda.max(1e-6); mean.len()];
        Prediction { mean, var }
    }

    fn name(&self) -> String {
        "MKA-Ridge".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::gp::metrics::smse;
    use crate::kernels::RbfKernel;

    #[test]
    fn ridge_learns_signal() {
        let data = gp_dataset(&SynthSpec::named("t", 150, 2), 7);
        let (tr, te) = data.split(0.9, 1);
        let cfg = MkaConfig { d_core: 24, block_size: 48, ..MkaConfig::default() };
        let m = MkaRidge::fit(&tr, &RbfKernel::new(1.0), 0.1, &cfg).unwrap();
        let pred = m.predict(&te.x);
        let e = smse(&te.y, &pred.mean);
        assert!(e < 0.9, "SMSE {e}");
        assert_eq!(m.name(), "MKA-Ridge");
        assert_eq!(m.lambda(), 0.1);
    }

    #[test]
    fn matches_exact_ridge_without_compression() {
        let data = gp_dataset(&SynthSpec::named("t", 50, 2), 8);
        let kern = RbfKernel::new(1.0);
        let cfg = MkaConfig { d_core: 100, ..MkaConfig::default() };
        let m = MkaRidge::fit(&data, &kern, 0.2, &cfg).unwrap();
        // exact ridge α via Cholesky
        let mut k = kern.gram_sym(&data.x);
        k.add_diag(0.2);
        let chol = crate::la::chol::Chol::new(&k).unwrap();
        let alpha = chol.solve(&data.y);
        for i in 0..50 {
            assert!((alpha[i] - m.alpha[i]).abs() < 1e-8);
        }
    }
}
