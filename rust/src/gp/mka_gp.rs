//! MKA-GP (paper §4.1): Gaussian process regression through the MKA
//! factorization of the **joint** train/test kernel matrix.
//!
//! Naively approximating K′ = K + σ²I and plugging K̃′⁻¹ into the GP mean
//! mixes an approximate inverse with exact cross-covariances k_x, which
//! biases the estimate. Nyström methods fix this by replacing k_x with its
//! own low-rank sketch; MKA is not low rank, so the paper instead
//! factorizes the joint matrix
//!
//!   𝒦 = [ K + σ²I   K_* ]
//!       [ K_*ᵀ      K_test ]
//!
//! and recovers Ǩ⁻¹ = A − B D⁻¹ C from the blocked inverse
//! 𝒦⁻¹ = [[A, B], [C, D]] (Schur complement of D), giving
//! f̂ = K_*ᵀ Ǩ⁻¹ y. All blocks of 𝒦⁻¹ are produced matrix-free through
//! Proposition 7 solves: one solve for (y; 0) and p solves for the test
//! unit vectors — O((n+p)·s) each after factorization.
//!
//! The same D block gives calibrated predictive variances: by the block
//! inverse identity D⁻¹ = K_test − K_*ᵀ(K+σ²I)⁻¹K_*, i.e. D⁻¹ *is* the
//! posterior covariance of the latent f at the test points.
//!
//! **Noise is a shift, not an input:** every factorization here is of the
//! noise-free gram, with σ² applied as the O(1)
//! [`crate::mka::MkaFactor::shifted`] spectrum view. The train-side
//! factor is built once (lazily) and reused across noise levels, so
//! [`MkaGp::set_noise`] re-tunes a fitted model — `log_marginal` at the
//! new σ² is pure spectrum arithmetic — without any refactorization.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use super::predict_cache::{data_fingerprint, JointEntry, PredictCache};
use super::{
    GpModel, ModelInfo, ObservePath, ObservePolicy, ObserveReport, ObserveUpdate, Prediction,
};
use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::kernels::gram::GramBuilder;
use crate::kernels::Kernel;
use crate::la::blas::dot;
use crate::la::dense::Mat;
use crate::la::lu::Lu;
use crate::mka::{extend_factorize, factorize, MkaConfig, MkaFactor};
use crate::obs;
use crate::par::arena;
use crate::util::json::Json;

/// MKA-based GP regressor (transductive: the joint factorization is built
/// per prediction batch over the train/test kernel; the train-only factor
/// backing `log_marginal` is built once and shared across noise levels).
pub struct MkaGp {
    train: Dataset,
    kernel: Box<dyn Kernel>,
    sigma2: f64,
    config: MkaConfig,
    gram: Option<GramBuilder>,
    /// Noise-free factorization of the train-only gram, built on first
    /// use. σ² enters as a spectrum shift, so noise re-tunes never touch
    /// this. A failure is stored as its message so it is sticky (the
    /// factorization is deterministic — retrying cannot succeed).
    train_factor: OnceLock<std::result::Result<MkaFactor, String>>,
    /// How many predictive variances the σ² floor has clamped over this
    /// model's lifetime (shared across [`MkaGp::retuned`] copies so the
    /// `diagnose` op sees one counter per logical model). Observational
    /// only — never read on the value path.
    floor_hits: Arc<AtomicU64>,
    /// Bounded LRU over noise-free joint factorizations, keyed on the
    /// model fingerprint + exact test-set bits. Shared across
    /// [`MkaGp::retuned`] copies (σ² is a shift view, so a retune keeps
    /// every entry hot); `observed`/refit/refresh build a fresh cache —
    /// the training set changed, so every held entry is stale.
    predict_cache: Arc<PredictCache>,
    /// The n×n noise-free train gram, memoized off the first assembly
    /// that builds it so later joint assemblies only compute the
    /// train×test and test×test tiles. Pure kernel evaluations — the
    /// memoized block is bit-identical to what a full joint assembly
    /// would recompute. Shared across `retuned` copies.
    train_gram: OnceLock<Arc<Mat>>,
    /// Lazily computed model fingerprint (training-set identity, kernel
    /// hyperparameter bits, MKA config scope) — the cache scope. σ² is
    /// deliberately absent.
    cache_scope: OnceLock<Vec<u64>>,
}

impl MkaGp {
    pub fn fit(
        train: &Dataset,
        kernel: &dyn Kernel,
        sigma2: f64,
        config: &MkaConfig,
    ) -> Result<MkaGp> {
        config.validate()?;
        if !(sigma2.is_finite() && sigma2 > 0.0) {
            return Err(Error::Config(format!(
                "MkaGp::fit: σ² must be finite and > 0, got {sigma2}"
            )));
        }
        Ok(MkaGp {
            train: train.clone(),
            kernel: kernel.boxed_clone(),
            sigma2,
            config: config.clone(),
            gram: None,
            train_factor: OnceLock::new(),
            floor_hits: Arc::new(AtomicU64::new(0)),
            predict_cache: Arc::new(PredictCache::with_default_capacity()),
            train_gram: OnceLock::new(),
            cache_scope: OnceLock::new(),
        })
    }

    /// Use a [`GramBuilder`] (possibly backed by the AOT XLA tile engine)
    /// for the O(n²) joint-kernel assembly.
    pub fn with_gram_builder(mut self, gram: GramBuilder) -> MkaGp {
        self.gram = Some(gram);
        self
    }

    /// The noise-free factorization of the train-only gram, computed on
    /// first use and shared by every subsequent `log_marginal` /
    /// [`MkaGp::set_noise`] cycle.
    pub fn train_factor(&self) -> Result<&MkaFactor> {
        let slot = self.train_factor.get_or_init(|| {
            // Same gram source as factorize_joint: the tile engine when a
            // builder is configured, native assembly otherwise.
            let k = match &self.gram {
                Some(g) => g.build_sym(&self.train.x),
                None => self.kernel.gram_sym(&self.train.x),
            };
            let f = factorize(&k, Some(&self.train.x), &self.config).map_err(|e| e.to_string());
            // The n×n block was just evaluated — memoize it so joint
            // assemblies skip the train×train tile entirely.
            let _ = self.train_gram.set(Arc::new(k));
            f
        });
        slot.as_ref().map_err(|m| Error::Linalg(m.clone()))
    }

    /// Current observation-noise variance σ².
    pub fn sigma2(&self) -> f64 {
        self.sigma2
    }

    /// Re-tune the observation noise of a fitted model **without
    /// refactorizing**: σ² only shifts the factor spectrum
    /// ([`MkaFactor::shifted`]), so the next `log_marginal` is pure
    /// spectrum arithmetic and the next `predict` factorizes exactly as
    /// often as it would have anyway (once per joint batch).
    pub fn set_noise(&mut self, sigma2: f64) -> Result<()> {
        if !(sigma2.is_finite() && sigma2 > 0.0) {
            return Err(Error::Config(format!(
                "set_noise: σ² must be finite and > 0, got {sigma2}"
            )));
        }
        self.sigma2 = sigma2;
        Ok(())
    }

    /// A copy of this model serving at noise `sigma2`, sharing the
    /// already-computed train factor (cheap: Arc'd stages) — the concrete
    /// form of [`GpModel::with_noise`], used by the sharded fleet to
    /// retune every shard in O(shards).
    pub fn retuned(&self, sigma2: f64) -> Result<MkaGp> {
        let mut m = MkaGp {
            train: self.train.clone(),
            kernel: self.kernel.boxed_clone(),
            sigma2: self.sigma2,
            config: self.config.clone(),
            gram: self.gram.clone(),
            train_factor: OnceLock::new(),
            floor_hits: Arc::clone(&self.floor_hits),
            // σ² is a shift view over cached (noise-free) joint factors,
            // so the retuned copy serves the SAME cache: a retune
            // republish never invalidates a hot entry.
            predict_cache: Arc::clone(&self.predict_cache),
            train_gram: OnceLock::new(),
            cache_scope: OnceLock::new(),
        };
        if let Some(slot) = self.train_factor.get() {
            let _ = m.train_factor.set(slot.clone());
        }
        if let Some(g) = self.train_gram.get() {
            let _ = m.train_gram.set(Arc::clone(g));
        }
        m.set_noise(sigma2)?;
        Ok(m)
    }

    /// Factorize the joint train/test kernel (exposed for diagnostics).
    /// The factorization itself is noise-free; the returned factor is the
    /// σ²-shifted view. This always builds — [`MkaGp::predict`] goes
    /// through the cached [`MkaGp::joint_entry`] path instead.
    pub fn factorize_joint(&self, x_test: &Mat) -> Result<(MkaFactor, Mat)> {
        // σ² on the whole joint diagonal, as a shift view. The paper's 𝒦
        // puts σ² on the train block only; by the block-inverse identity
        // A − B D⁻¹ C = (K + σ²I)⁻¹ *independently of the test block*, so
        // the mean is unchanged in exact arithmetic — but λ_min(𝒦) ≥ σ²
        // makes the factorized inverse numerically robust, and D⁻¹ becomes
        // the noise-inclusive predictive covariance directly. Under the
        // default (shift-invariant) pivot rules this is exactly
        // `factorize(𝒦_noise-free + σ²I)` at the cost of factorizing the
        // noise-free matrix once; see `mka::factor` for the SPCA caveat.
        let (f, kstar) = self.joint_noise_free(x_test)?;
        Ok((f.shifted(self.sigma2), kstar))
    }

    /// Assemble and factorize the **noise-free** joint train/test kernel
    /// — the quantity the predict cache stores. When the n×n train gram
    /// is already memoized, only the train×test and test×test tiles are
    /// freshly evaluated; each gram entry is an independent function of
    /// its point pair, so tiled assembly is bit-identical to a full
    /// joint rebuild.
    fn joint_noise_free(&self, x_test: &Mat) -> Result<(MkaFactor, Mat)> {
        let n = self.train.n();
        let p = x_test.rows;
        let _sp = obs::span!("gp.factorize_joint n={n} p={p}");
        // Joint coordinates from the worker arena: the two set_blocks
        // cover every row.
        let mut xj = arena::take_mat(n + p, self.train.x.cols);
        xj.set_block(0, 0, &self.train.x);
        xj.set_block(n, 0, x_test);
        let kj = match self.train_gram.get() {
            Some(ktr) => {
                let _sp = obs::span!("gp.joint_tiles n={n} p={p}");
                let mut kj = arena::take_mat(n + p, n + p);
                kj.set_block(0, 0, ktr.as_ref());
                let kcross = match &self.gram {
                    Some(g) => g.build(&self.train.x, x_test),
                    None => self.kernel.gram(&self.train.x, x_test),
                };
                for i in 0..n {
                    kj.row_mut(i)[n..n + p].copy_from_slice(kcross.row(i));
                }
                for j in 0..p {
                    for i in 0..n {
                        kj.set(n + j, i, kcross.at(i, j));
                    }
                }
                let ktest = match &self.gram {
                    Some(g) => g.build_sym(x_test),
                    None => self.kernel.gram_sym(x_test),
                };
                kj.set_block(n, n, &ktest);
                arena::give_mat(kcross);
                arena::give_mat(ktest);
                kj
            }
            None => {
                let kj = match &self.gram {
                    Some(g) => g.build_sym(&xj),
                    None => self.kernel.gram_sym(&xj),
                };
                // Memoize the train×train block off this assembly (free:
                // the entries were just evaluated) so later joint builds
                // skip the O(n²) tile.
                let mut ktr = Mat::zeros(n, n);
                for i in 0..n {
                    ktr.row_mut(i).copy_from_slice(&kj.row(i)[..n]);
                }
                let _ = self.train_gram.set(Arc::new(ktr));
                kj
            }
        };
        let f = factorize(&kj, Some(&xj), &self.config)?;
        // K_* block (n×p) for the mean formula (off-diagonal — the shift
        // never touches it). Copied out so the joint gram and coordinates
        // can be donated back immediately. NOT arena-backed: cached
        // entries outlive any worker scope.
        let mut kstar = Mat::zeros(n, p);
        for i in 0..n {
            kstar.row_mut(i).copy_from_slice(&kj.row(i)[n..n + p]);
        }
        arena::give_mat(kj);
        arena::give_mat(xj);
        Ok((f, kstar))
    }

    /// The model fingerprint the predict cache scopes entries under:
    /// training-set identity (n, dim, exact data bits), kernel
    /// hyperparameter bits and the MKA config scope. σ² is deliberately
    /// absent — entries are noise-free and served through `shifted`.
    fn scope(&self) -> &[u64] {
        self.cache_scope.get_or_init(|| {
            let mut s = Vec::with_capacity(16);
            s.push(self.train.n() as u64);
            s.push(self.train.dim() as u64);
            s.push(data_fingerprint(&self.train.x, &self.train.y));
            s.extend(self.kernel.fingerprint());
            s.extend(crate::train::mll::mka_scope(&self.config));
            s
        })
    }

    /// The cached joint factorization for `x_test` (built on miss).
    /// Returns the **noise-free** entry plus whether this lookup hit —
    /// consumers apply [`MkaFactor::shifted`] at the point of use.
    fn joint_entry(&self, x_test: &Mat) -> Result<(Arc<JointEntry>, bool)> {
        let (entry, hit) = self.predict_cache.get_or_build(self.scope(), x_test, || {
            let (factor, kstar) = self.joint_noise_free(x_test)?;
            Ok(JointEntry { x_test: x_test.clone(), factor, kstar })
        })?;
        if hit {
            let p = x_test.rows;
            let _sp = obs::span!("gp.predict_cache_hit p={p}");
            obs::log!(
                Debug,
                "gp.predict_cache",
                { "n" => self.train.n(), "p" => p },
                "joint factor served from cache — zero factorizations"
            );
        }
        Ok((entry, hit))
    }

    /// This model's joint-factor predict cache (shared across `retuned`
    /// copies; fresh after any training-set change).
    pub fn predict_cache(&self) -> &PredictCache {
        &self.predict_cache
    }

    pub fn d_core(&self) -> usize {
        self.config.d_core
    }

    /// Approximate log marginal likelihood of the training targets,
    /// −½ yᵀK̃′⁻¹y − ½ log det K̃′ − (n/2) log 2π, using the direct
    /// solve + logdet of the factorization (Proposition 7). This is the
    /// quantity the paper highlights for hyperparameter learning ("small
    /// errors can be compounded in the process of learning hyperparameters
    /// through log-likelihood maximization"). The train factor is built
    /// once; evaluations at other noise levels (after
    /// [`MkaGp::set_noise`]) reuse it through the shift view.
    pub fn log_marginal(&self) -> Result<f64> {
        let f = self.train_factor()?.shifted(self.sigma2);
        let alpha = f.solve(&self.train.y)?;
        let quad: f64 = self.train.y.iter().zip(&alpha).map(|(a, b)| a * b).sum();
        let n = self.train.n() as f64;
        Ok(-0.5 * quad - 0.5 * f.logdet()? - 0.5 * n * (2.0 * std::f64::consts::PI).ln())
    }

    /// Streaming append: a copy of this model extended with the batch
    /// `(xb, yb)` — incrementally when the gates allow it, through a
    /// windowed full re-fit otherwise — plus the [`ObserveReport`] saying
    /// which path ran and what it reused.
    ///
    /// The incremental path extends the stored train factor with
    /// [`crate::mka::extend_factorize`]: every old block's rotation is
    /// replayed verbatim (so the old×old reconstruction is bit-identical
    /// and untouched stages are shared, never refactorized), new points are
    /// compressed among themselves under their nearest old cluster, and σ²
    /// stays the usual [`MkaFactor::shifted`] view. Because `predict` is
    /// transductive (per-batch joint factorization over the *stored*
    /// training set), predictions after an incremental observe are
    /// identical to a fresh fit on the concatenated data; the extension's
    /// approximation surfaces only in `log_marginal`/`diagnose`, which is
    /// exactly what the two gates guard:
    ///
    /// 1. **drift** — mean standardized squared residual of the current
    ///    model on the incoming batch exceeds `policy.drift_threshold`;
    /// 2. **core growth** — the extended factor's final core has grown past
    ///    `policy.max_core_growth × d_core` (the identity ride-through has
    ///    stopped compressing).
    pub fn observed(
        &self,
        xb: &Mat,
        yb: &[f64],
        policy: &ObservePolicy,
    ) -> Result<(MkaGp, ObserveReport)> {
        policy.validate()?;
        let b = xb.rows;
        let n = self.train.n();
        if b == 0 {
            return Err(Error::Data("observe: empty batch".into()));
        }
        if yb.len() != b {
            return Err(Error::Data(format!(
                "observe: x has {b} rows but y has {} entries",
                yb.len()
            )));
        }
        if xb.cols != self.train.dim() {
            return Err(Error::Data(format!(
                "observe: batch dim {} != training dim {}",
                xb.cols,
                self.train.dim()
            )));
        }
        for i in 0..b {
            if !(xb.row(i).iter().all(|v| v.is_finite()) && yb[i].is_finite()) {
                return Err(Error::Data(format!(
                    "observe: non-finite value in batch row {i}"
                )));
            }
        }
        let _sp = obs::span!("gp.observe n={n} b={b}");

        // Gate 1: predictive drift of the CURRENT model on the incoming
        // batch, through the stored train factor (k* solves only — no
        // joint factorization, no new `factorize_count`). The statistic
        // is mean((y−μ̂)²/σ̂²) with σ̂² ≥ σ², so it is well-defined for any
        // batch size, b = 1 included.
        let drift = self.batch_drift(xb, yb)?;
        if drift > policy.drift_threshold {
            let reason = format!(
                "predictive drift {drift:.3} exceeds threshold {}",
                policy.drift_threshold
            );
            let m = self.refit_windowed(self.extended_dataset(xb, yb), policy, &reason)?;
            let n_total = m.train.n();
            return Ok((
                m,
                ObserveReport {
                    path: ObservePath::Refit,
                    reason: Some(reason),
                    appended: b,
                    n_total,
                    drift,
                    stats: None,
                },
            ));
        }

        // Incremental extension of the stored (noise-free) train factor.
        let ext = self.extended_dataset(xb, yb);
        let kj = match &self.gram {
            Some(g) => g.build_sym(&ext.x),
            None => self.kernel.gram_sym(&ext.x),
        };
        let (f, stats) = extend_factorize(self.train_factor()?, &kj, &self.config)?;

        // Gate 2: compression degradation. New coords ride the deeper
        // stages uncompressed, so the final core grows with every observe;
        // past the budget the factor has stopped being multiresolution.
        let growth = f.d_core() as f64 / self.config.d_core.max(1) as f64;
        if growth > policy.max_core_growth {
            let reason = format!(
                "core growth ×{growth:.2} exceeds budget ×{}",
                policy.max_core_growth
            );
            let m = self.refit_windowed(ext, policy, &reason)?;
            let n_total = m.train.n();
            return Ok((
                m,
                ObserveReport {
                    path: ObservePath::Refit,
                    reason: Some(reason),
                    appended: b,
                    n_total,
                    drift,
                    stats: None,
                },
            ));
        }

        let n_total = ext.n();
        let m = MkaGp {
            train: ext,
            kernel: self.kernel.boxed_clone(),
            sigma2: self.sigma2,
            config: self.config.clone(),
            gram: self.gram.clone(),
            train_factor: OnceLock::new(),
            floor_hits: Arc::clone(&self.floor_hits),
            // The training set changed: every cached joint factor (and
            // the memoized train gram) is stale. The updated model gets
            // fresh, empty instances; the republish drops the old Arc —
            // the scope-precise invalidation the sharded fleet rides
            // (untouched shards go through `retuned` and keep theirs).
            predict_cache: Arc::new(PredictCache::with_default_capacity()),
            train_gram: OnceLock::new(),
            cache_scope: OnceLock::new(),
        };
        let _ = m.train_factor.set(Ok(f));
        Ok((
            m,
            ObserveReport {
                path: ObservePath::Incremental,
                reason: None,
                appended: b,
                n_total,
                drift,
                stats: Some(stats),
            },
        ))
    }

    /// Background refresh: a from-scratch refit on the currently-held
    /// training set (factor forced eagerly, so the returned model serves
    /// `log_marginal`/`diagnose` without lazy work) — what the recurring
    /// refresh scheduler runs.
    pub fn refreshed_model(&self) -> Result<MkaGp> {
        let mut m = MkaGp::fit(&self.train, self.kernel.as_ref(), self.sigma2, &self.config)?;
        if let Some(g) = &self.gram {
            m = m.with_gram_builder(g.clone());
        }
        m.train_factor()?;
        Ok(m)
    }

    /// Mean standardized squared residual of this model on `(xb, yb)`:
    /// mean((y − μ̂)²/σ̂²) with μ̂, σ̂² from the stored train factor (σ̂²
    /// floored at σ², so the statistic never blows up). ≈ 1 when the model
    /// is calibrated for the batch.
    fn batch_drift(&self, xb: &Mat, yb: &[f64]) -> Result<f64> {
        let f = self.train_factor()?.shifted(self.sigma2);
        let alpha = f.solve(&self.train.y)?;
        let n = self.train.n();
        let b = xb.rows;
        let mut kstar = Mat::zeros(n, b);
        for j in 0..b {
            let ks = self.kernel.cross(xb.row(j), &self.train.x);
            for (i, v) in ks.iter().enumerate() {
                kstar.set(i, j, *v);
            }
        }
        let sol = f.solve_mat_par(&kstar, self.config.n_threads)?;
        let mut acc = 0.0;
        for j in 0..b {
            let ks = kstar.col(j);
            let mu = dot(&ks, &alpha);
            let var = (self.kernel.eval(xb.row(j), xb.row(j)) + self.sigma2
                - dot(&ks, &sol.col(j)))
            .max(self.sigma2);
            let r = yb[j] - mu;
            acc += r * r / var;
        }
        Ok(acc / b as f64)
    }

    /// The training set with the batch appended (new points at the tail —
    /// the index convention `extend_factorize` relies on).
    fn extended_dataset(&self, xb: &Mat, yb: &[f64]) -> Dataset {
        let n = self.train.n();
        let mut x = Mat::zeros(n + xb.rows, self.train.dim());
        x.set_block(0, 0, &self.train.x);
        x.set_block(n, 0, xb);
        let mut y = self.train.y.clone();
        y.extend_from_slice(yb);
        Dataset::new(self.train.name.clone(), x, y)
    }

    /// The gated fallback: full re-fit on `ext`, optionally windowed to the
    /// most recent `policy.window` points, factor forced eagerly so the
    /// result is byte-for-byte a fresh fit.
    fn refit_windowed(&self, ext: Dataset, policy: &ObservePolicy, reason: &str) -> Result<MkaGp> {
        let kept = if policy.window > 0 && policy.window < ext.n() {
            let lo = ext.n() - policy.window;
            let idx: Vec<usize> = (lo..ext.n()).collect();
            ext.subset(&idx)
        } else {
            ext
        };
        obs::log!(
            Warn,
            "gp.observe",
            { "n" => kept.n(), "window" => policy.window },
            "drift gate forced a windowed refit: {reason}"
        );
        let mut m = MkaGp::fit(&kept, self.kernel.as_ref(), self.sigma2, &self.config)?;
        if let Some(g) = &self.gram {
            m = m.with_gram_builder(g.clone());
        }
        m.train_factor()?;
        Ok(m)
    }
}

impl GpModel for MkaGp {
    fn predict(&self, x_test: &Mat) -> Prediction {
        let n = self.train.n();
        let p = x_test.rows;
        let _sp = obs::span!("gp.predict n={n} p={p}");
        let (entry, _hit) = match self.joint_entry(x_test) {
            Ok(v) => v,
            Err(e) => {
                // Degenerate fallback: predict the prior.
                obs::log!(
                    Warn,
                    "gp.mka",
                    { "n" => n, "p" => p },
                    "joint factorization failed, serving the prior: {e}"
                );
                return Prediction {
                    mean: vec![0.0; p],
                    var: vec![1.0 + self.sigma2; p],
                };
            }
        };
        // The cached factor is noise-free; σ² enters here as the O(1)
        // shift view — which is why a retune republish keeps entries hot.
        let f = entry.factor.shifted(self.sigma2);
        let kstar = &entry.kstar;

        // 𝒦⁻¹ (y; 0) → C y (test part). With the blocked-inverse identity
        // C = −D K_*ᵀ (K+σ²I)⁻¹, the GP mean is recovered as
        //   f̂ = K_*ᵀ(K+σ²I)⁻¹ y = −D⁻¹ (C y),
        // where every factor comes from the SAME approximation 𝒦̃ — the
        // paper's "consistent with the off-diagonal block K_*" estimator.
        // Because f̂ is then the exact posterior mean under the (valid,
        // spsd) modified prior 𝒦̃, it degrades gracefully with
        // approximation error instead of amplifying it the way the naive
        // mix of exact k_x with an approximate inverse does (§4.1).
        //
        // All p+1 right-hand sides — (y; 0) for the mean and the p test
        // unit vectors for the D block — ride ONE blocked cascade
        // (column 0 is (y; 0), column 1+j is e_{n+j}), instead of p+1
        // serial solves each re-walking every rotation.
        let mut rhs = arena::take_mat_zeroed(n + p, p + 1);
        for (i, &yi) in self.train.y.iter().enumerate() {
            rhs.set(i, 0, yi);
        }
        for j in 0..p {
            rhs.set(n + j, j + 1, 1.0);
        }
        let sol = {
            let _sp = obs::span!("gp.solve rhs={}x{}", n + p, p + 1);
            match f.solve_mat_par(&rhs, self.config.n_threads) {
                Ok(s) => s,
                Err(e) => {
                    obs::log!(
                        Warn,
                        "gp.mka",
                        { "n" => n, "p" => p },
                        "cascade solve failed, serving the prior: {e}"
                    );
                    return Prediction { mean: vec![0.0; p], var: vec![1.0 + self.sigma2; p] };
                }
            }
        };
        arena::give_mat(rhs);
        let cy: Vec<f64> = (0..p).map(|i| sol.at(n + i, 0)).collect();

        // D block of 𝒦̃⁻¹: test rows of the unit-vector solutions.
        let mut d_block = arena::take_mat_zeroed(p, p);
        for j in 0..p {
            for i in 0..p {
                d_block.set(i, j, sol.at(n + i, j + 1));
            }
        }
        d_block.symmetrize();

        let lu = match Lu::new(&d_block) {
            Ok(lu) => lu,
            Err(e) => {
                // D numerically singular — fall back to the naive
                // (inconsistent) estimator f̂ = K_*ᵀ [𝒦̃⁻¹(y;0)]_train.
                obs::log!(
                    Warn,
                    "gp.mka",
                    { "n" => n, "p" => p },
                    "D block singular, naive-estimator fallback: {e}"
                );
                let ay: Vec<f64> = (0..n).map(|i| sol.at(i, 0)).collect();
                let mean = (0..p).map(|j| dot(&kstar.col(j), &ay)).collect();
                return Prediction { mean, var: vec![1.0 + self.sigma2; p] };
            }
        };
        arena::give_mat(sol);
        arena::give_mat(d_block);
        // `kstar` lives in the cache entry — never donated to the arena.

        // Mean: f̂ = −D⁻¹ (C y).
        let w = lu.solve(&cy);
        let mean: Vec<f64> = w.iter().map(|v| -v).collect();

        // Variance: with σ² on the full joint diagonal,
        // D⁻¹ = K_test + σ²I − K_*ᵀ(K+σ²I)⁻¹K_* — the noise-inclusive
        // predictive covariance. Its diagonal is ≥ σ² in exact arithmetic
        // (the latent Schur complement of the spsd 𝒦̃ is psd), so the
        // noise variance itself is the tight floor against LU roundoff —
        // predictive variance can never undercut the observation noise.
        let dinv = lu.inverse();
        let clamped = (0..p).filter(|&j| dinv.at(j, j) < self.sigma2).count();
        if clamped > 0 {
            self.floor_hits.fetch_add(clamped as u64, Ordering::Relaxed);
        }
        let var: Vec<f64> =
            (0..p).map(|j| dinv.at(j, j).max(self.sigma2)).collect();

        Prediction { mean, var }
    }

    fn name(&self) -> String {
        format!("MKA(d={})", self.config.d_core)
    }

    fn with_noise(&self, sigma2: f64) -> Option<Box<dyn GpModel>> {
        Some(Box::new(self.retuned(sigma2).ok()?))
    }

    fn info(&self) -> ModelInfo {
        ModelInfo {
            method: self.name(),
            n: self.train.n(),
            dim: self.train.dim(),
            sigma2: Some(self.sigma2),
            shards: 1,
            shard_sizes: Vec::new(),
        }
    }

    fn diagnose(&self) -> Option<Json> {
        // Strictly from held state: `.get()` never forces the lazy train
        // factorization (forcing would bump `mka::factorize_count` behind
        // the caller's back — diagnostics must not change what work ran).
        let factor = match self.train_factor.get() {
            Some(Ok(f)) => f.shifted(self.sigma2).health().to_json(),
            Some(Err(m)) => Json::obj().with("error", Json::Str(m.clone())),
            None => Json::Null,
        };
        Some(
            Json::obj()
                .with("kind", Json::Str("mka".into()))
                .with("method", Json::Str(self.name()))
                .with("n", Json::Num(self.train.n() as f64))
                .with("dim", Json::Num(self.train.dim() as f64))
                .with("sigma2", Json::Num(self.sigma2))
                .with(
                    "variance_floor_hits",
                    Json::Num(self.floor_hits.load(Ordering::Relaxed) as f64),
                )
                .with(
                    "predict_cache",
                    Json::obj()
                        .with("capacity", Json::Num(self.predict_cache.capacity() as f64))
                        .with("entries", Json::Num(self.predict_cache.len() as f64))
                        .with("hits", Json::Num(self.predict_cache.hits() as f64))
                        .with("misses", Json::Num(self.predict_cache.misses() as f64))
                        .with(
                            "evictions",
                            Json::Num(self.predict_cache.evictions() as f64),
                        ),
                )
                .with("factor", factor),
        )
    }

    fn observe(
        &self,
        x: &Mat,
        y: &[f64],
        policy: &ObservePolicy,
    ) -> Option<Result<ObserveUpdate>> {
        Some(self.observed(x, y, policy).map(|(m, rep)| ObserveUpdate {
            model: Box::new(m),
            report: rep.to_json(),
        }))
    }

    fn can_refresh(&self) -> bool {
        true
    }

    fn refreshed(&self) -> Option<Result<Box<dyn GpModel>>> {
        Some(self.refreshed_model().map(|m| Box::new(m) as Box<dyn GpModel>))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::gp::full::FullGp;
    use crate::gp::metrics::{mnlp, smse};
    use crate::kernels::RbfKernel;

    fn config(d: usize) -> MkaConfig {
        MkaConfig { d_core: d, block_size: 48, ..MkaConfig::default() }
    }

    #[test]
    fn close_to_full_gp_on_small_data() {
        let data = gp_dataset(&SynthSpec::named("t", 160, 2), 3);
        let (tr, te) = data.split(0.9, 1);
        let kern = RbfKernel::new(1.0);
        let full = FullGp::fit(&tr, &kern, 0.1).unwrap();
        let mka = MkaGp::fit(&tr, &kern, 0.1, &config(24)).unwrap();
        let pf = full.predict(&te.x);
        let pm = mka.predict(&te.x);
        let e_full = smse(&te.y, &pf.mean);
        let e_mka = smse(&te.y, &pm.mean);
        // MKA should track Full closely — within a modest factor.
        assert!(
            e_mka < (3.0 * e_full).max(0.5),
            "full={e_full} mka={e_mka}"
        );
        let nl = mnlp(&te.y, &pm.mean, &pm.var);
        assert!(nl.is_finite());
    }

    #[test]
    fn exact_when_core_holds_everything() {
        // d_core ≥ n+p ⇒ no compression ⇒ identical to the exact GP.
        let data = gp_dataset(&SynthSpec::named("t", 60, 2), 4);
        let (tr, te) = data.split(0.85, 2);
        let kern = RbfKernel::new(1.0);
        let full = FullGp::fit(&tr, &kern, 0.1).unwrap();
        let mka = MkaGp::fit(&tr, &kern, 0.1, &config(100)).unwrap();
        let pf = full.predict(&te.x);
        let pm = mka.predict(&te.x);
        for i in 0..te.n() {
            assert!(
                (pf.mean[i] - pm.mean[i]).abs() < 1e-6,
                "mean[{i}]: full={} mka={}",
                pf.mean[i],
                pm.mean[i]
            );
            // latent var + σ² must match the exact predictive variance
            assert!(
                (pf.var[i] - pm.var[i]).abs() < 1e-6,
                "var[{i}]: full={} mka={}",
                pf.var[i],
                pm.var[i]
            );
        }
    }

    #[test]
    fn variances_positive_and_sane() {
        let data = gp_dataset(&SynthSpec::named("t", 120, 3), 5);
        let (tr, te) = data.split(0.9, 3);
        let mka = MkaGp::fit(&tr, &RbfKernel::new(0.8), 0.1, &config(16)).unwrap();
        let pred = mka.predict(&te.x);
        for &v in &pred.var {
            assert!(v >= 0.1 - 1e-12 && v < 10.0, "var={v}");
        }
    }

    /// The predictive variance floor is exactly σ²: with σ² on the whole
    /// joint diagonal, diag(D⁻¹) ≥ σ² in exact arithmetic, so even under
    /// heavy compression no reported variance may undercut the noise.
    #[test]
    fn variance_never_below_noise_floor() {
        let data = gp_dataset(&SynthSpec::named("t", 150, 2), 8);
        let (tr, te) = data.split(0.85, 4);
        for s2 in [0.02, 0.1, 0.5] {
            // aggressive compression to stress the D-block arithmetic
            let cfg = MkaConfig { d_core: 8, block_size: 24, ..MkaConfig::default() };
            let mka = MkaGp::fit(&tr, &RbfKernel::new(0.9), s2, &cfg).unwrap();
            let pred = mka.predict(&te.x);
            for &v in &pred.var {
                assert!(v >= s2, "var {v} < σ² {s2}");
            }
        }
    }

    /// `set_noise` must be indistinguishable from a fresh fit at the new
    /// σ² — predictions and evidence both route through the same
    /// noise-free factorizations plus a shift.
    #[test]
    fn set_noise_matches_refit() {
        let data = gp_dataset(&SynthSpec::named("t", 140, 2), 6);
        let (tr, te) = data.split(0.85, 5);
        let kern = RbfKernel::new(1.1);
        let mut tuned = MkaGp::fit(&tr, &kern, 0.1, &config(20)).unwrap();
        let ml_before = tuned.log_marginal().unwrap();
        tuned.set_noise(0.03).unwrap();
        assert_eq!(tuned.sigma2(), 0.03);
        let fresh = MkaGp::fit(&tr, &kern, 0.03, &config(20)).unwrap();
        // evidence: identical arithmetic (same factor, same shift)
        let ml_tuned = tuned.log_marginal().unwrap();
        let ml_fresh = fresh.log_marginal().unwrap();
        assert!(
            (ml_tuned - ml_fresh).abs() < 1e-9 * ml_fresh.abs().max(1.0),
            "retuned {ml_tuned} vs fresh {ml_fresh}"
        );
        assert!(ml_tuned != ml_before, "noise change must move the evidence");
        // predictions: same joint factorization path, same shift
        let pt = tuned.predict(&te.x);
        let pf = fresh.predict(&te.x);
        for i in 0..te.n() {
            assert!((pt.mean[i] - pf.mean[i]).abs() < 1e-10, "mean[{i}]");
            assert!((pt.var[i] - pf.var[i]).abs() < 1e-10, "var[{i}]");
        }
        // invalid noise is rejected without touching the model
        assert!(tuned.set_noise(-1.0).is_err());
        assert!(tuned.set_noise(f64::NAN).is_err());
        assert_eq!(tuned.sigma2(), 0.03);
    }

    /// The `GpModel::with_noise` hook (serving-plane `retune`) produces a
    /// model equivalent to a fresh fit; non-MKA models opt out with None.
    #[test]
    fn with_noise_trait_retunes() {
        let data = gp_dataset(&SynthSpec::named("t", 100, 2), 7);
        let (tr, te) = data.split(0.85, 6);
        let kern = RbfKernel::new(1.0);
        let mka = MkaGp::fit(&tr, &kern, 0.1, &config(16)).unwrap();
        let retuned = mka.with_noise(0.25).expect("MKA supports retune");
        let fresh = MkaGp::fit(&tr, &kern, 0.25, &config(16)).unwrap();
        let pr = retuned.predict(&te.x);
        let pf = fresh.predict(&te.x);
        for i in 0..te.n() {
            assert!((pr.mean[i] - pf.mean[i]).abs() < 1e-10);
            assert!((pr.var[i] - pf.var[i]).abs() < 1e-10);
        }
        // invalid σ² refuses the retune
        assert!(mka.with_noise(0.0).is_none());
        // the default implementation opts out
        let full = FullGp::fit(&tr, &kern, 0.1).unwrap();
        assert!(full.with_noise(0.2).is_none());
    }

    #[test]
    fn log_marginal_tracks_full_gp() {
        let data = gp_dataset(&SynthSpec::named("t", 150, 2), 9);
        let kern = RbfKernel::new(1.0);
        let full = FullGp::fit(&data, &kern, 0.1).unwrap();
        let exact = full.log_marginal(&data.y);
        // gentle compression: within ~10% of the exact value
        let cfg = MkaConfig { d_core: 96, block_size: 75, gamma: 0.7, ..MkaConfig::default() };
        let mka = MkaGp::fit(&data, &kern, 0.1, &cfg).unwrap();
        let approx = mka.log_marginal().unwrap();
        assert!(
            (exact - approx).abs() < 0.10 * exact.abs(),
            "exact {exact} vs approx {approx}"
        );
        // ordering across hyperparameters is preserved (what CV/LML tuning
        // actually needs): a terrible lengthscale scores worse in both.
        let bad_kern = RbfKernel::new(1e-3);
        let full_bad = FullGp::fit(&data, &bad_kern, 0.1).unwrap().log_marginal(&data.y);
        let mka_bad = MkaGp::fit(&data, &bad_kern, 0.1, &cfg).unwrap().log_marginal().unwrap();
        assert!(full_bad < exact);
        assert!(mka_bad < approx, "LML ordering flipped: {mka_bad} vs {approx}");
    }

    #[test]
    fn parallel_predict_matches_serial() {
        // Enough test points to cross the column-parallel threshold; the
        // sharded cascade must reproduce the serial blocked result.
        let data = gp_dataset(&SynthSpec::named("t", 200, 2), 11);
        let (tr, te) = data.split(0.75, 7);
        assert!(te.n() >= 32, "need a wide RHS block, got {}", te.n());
        let kern = RbfKernel::new(1.0);
        let serial = MkaGp::fit(&tr, &kern, 0.1, &config(24)).unwrap();
        let par_cfg = MkaConfig { n_threads: 4, ..config(24) };
        let parallel = MkaGp::fit(&tr, &kern, 0.1, &par_cfg).unwrap();
        let ps = serial.predict(&te.x);
        let pp = parallel.predict(&te.x);
        for i in 0..te.n() {
            assert!((ps.mean[i] - pp.mean[i]).abs() < 1e-9, "mean[{i}]");
            assert!((ps.var[i] - pp.var[i]).abs() < 1e-9, "var[{i}]");
        }
    }

    /// `diagnose` reports only what is already held: before anything
    /// forces the train factor it says so (`factor: null`), afterwards it
    /// carries the shifted-spectrum health — and calling it never triggers
    /// a factorization either way.
    #[test]
    fn diagnose_never_forces_the_train_factor() {
        use crate::mka::factorize_count;
        let data = gp_dataset(&SynthSpec::named("t", 80, 2), 12);
        let mka = MkaGp::fit(&data, &RbfKernel::new(1.0), 0.1, &config(12)).unwrap();
        let before = factorize_count();
        let d = mka.diagnose().expect("MKA always reports");
        assert_eq!(factorize_count(), before, "diagnose must not factorize");
        assert!(matches!(d.get("factor"), Some(Json::Null)));
        assert_eq!(d.str_field("kind"), Some("mka"));
        assert_eq!(d.num_field("n"), Some(80.0));
        assert_eq!(d.num_field("variance_floor_hits"), Some(0.0));
        // Force the train factor through normal use, then re-diagnose.
        mka.log_marginal().unwrap();
        let after_lml = factorize_count();
        let d = mka.diagnose().unwrap();
        assert_eq!(factorize_count(), after_lml, "diagnose must not refactorize");
        let f = d.get("factor").expect("factor health present");
        assert_eq!(f.num_field("n"), Some(80.0));
        assert!(f.num_field("condition").unwrap() >= 1.0);
        assert!(f.num_field("lambda_min").unwrap() >= 0.1 - 1e-12, "σ² shift floors λ_min");
        // A retuned copy shares state: still no new factorization.
        let re = mka.retuned(0.3).unwrap();
        let dr = re.diagnose().unwrap();
        assert_eq!(factorize_count(), after_lml);
        assert_eq!(dr.num_field("sigma2"), Some(0.3));
        assert!(dr.get("factor").unwrap().num_field("lambda_min").unwrap() >= 0.3 - 1e-12);
    }

    /// The incremental observe path must (a) not refactorize anything when
    /// the train factor is already built, (b) reuse stages provably, and
    /// (c) predict exactly like a fresh fit on the concatenated data —
    /// `predict` is transductive, so the equivalence is bitwise.
    #[test]
    fn incremental_observe_matches_fresh_fit_predictions() {
        let data = gp_dataset(&SynthSpec::named("t", 128, 2), 21);
        let (base, newer) = data.split(0.875, 0); // 112 old + 16 new
        let kern = RbfKernel::new(1.0);
        let cfg = MkaConfig { d_core: 12, block_size: 32, ..MkaConfig::default() };
        let mka = MkaGp::fit(&base, &kern, 0.1, &cfg).unwrap();
        mka.train_factor().unwrap(); // pre-build: observe must add nothing
        let (obs, rep) = mka
            .observed(&newer.x, &newer.y, &ObservePolicy::default())
            .unwrap();
        // (strict factorize_count accounting lives in the dedicated
        // observe_equivalence suite, where tests serialize on a mutex —
        // the lib binary runs tests concurrently, so global counters are
        // only monotone here)
        assert_eq!(rep.path, ObservePath::Incremental);
        assert_eq!(rep.appended, newer.n());
        assert_eq!(rep.n_total, base.n() + newer.n());
        let stats = rep.stats.expect("incremental path reports stage stats");
        assert!(stats.stages_rebuilt < stats.stages_total, "some stages must be reused");
        assert!(stats.stages_reused >= 1);
        // fresh fit on the concatenated data: identical predictions
        let mut ext = base.clone();
        let mut x = Mat::zeros(base.n() + newer.n(), base.dim());
        x.set_block(0, 0, &base.x);
        x.set_block(base.n(), 0, &newer.x);
        ext.x = x;
        ext.y.extend_from_slice(&newer.y);
        let fresh = MkaGp::fit(&ext, &kern, 0.1, &cfg).unwrap();
        let te = gp_dataset(&SynthSpec::named("q", 24, 2), 22);
        let po = obs.predict(&te.x);
        let pf = fresh.predict(&te.x);
        for i in 0..te.n() {
            assert_eq!(po.mean[i].to_bits(), pf.mean[i].to_bits(), "mean[{i}]");
            assert_eq!(po.var[i].to_bits(), pf.var[i].to_bits(), "var[{i}]");
        }
        // the extended factor serves the evidence without lazy work
        assert!(obs.log_marginal().unwrap().is_finite());
    }

    /// Far-off-manifold targets trip the drift gate; the refit path is a
    /// genuine fresh fit (EXACT equivalence) and warns through obs.
    #[test]
    fn drift_gate_refit_is_exactly_a_fresh_fit() {
        let data = gp_dataset(&SynthSpec::named("t", 100, 2), 23);
        let (base, newer) = data.split(0.9, 1);
        let kern = RbfKernel::new(1.0);
        let cfg = MkaConfig { d_core: 12, block_size: 32, ..MkaConfig::default() };
        let mka = MkaGp::fit(&base, &kern, 0.1, &cfg).unwrap();
        let wild: Vec<f64> = newer.y.iter().map(|v| v + 500.0).collect();
        let (obs, rep) = mka
            .observed(&newer.x, &wild, &ObservePolicy::default())
            .unwrap();
        assert_eq!(rep.path, ObservePath::Refit);
        assert!(rep.drift > 16.0, "drift {}", rep.drift);
        assert!(rep.reason.unwrap().contains("drift"));
        assert!(rep.stats.is_none());
        let mut ext = base.clone();
        let mut x = Mat::zeros(base.n() + newer.n(), base.dim());
        x.set_block(0, 0, &base.x);
        x.set_block(base.n(), 0, &newer.x);
        ext.x = x;
        ext.y.extend_from_slice(&wild);
        let fresh = MkaGp::fit(&ext, &kern, 0.1, &cfg).unwrap();
        let te = gp_dataset(&SynthSpec::named("q", 16, 2), 24);
        let po = obs.predict(&te.x);
        let pf = fresh.predict(&te.x);
        for i in 0..te.n() {
            assert_eq!(po.mean[i].to_bits(), pf.mean[i].to_bits(), "mean[{i}]");
            assert_eq!(po.var[i].to_bits(), pf.var[i].to_bits(), "var[{i}]");
        }
        // evidence too: both route through an eagerly-built train factor
        let lo = obs.log_marginal().unwrap();
        let lf = fresh.log_marginal().unwrap();
        assert_eq!(lo.to_bits(), lf.to_bits());
    }

    /// `window` caps the refit training set at the most recent points.
    #[test]
    fn windowed_refit_keeps_the_tail() {
        let data = gp_dataset(&SynthSpec::named("t", 90, 2), 25);
        let (base, newer) = data.split(0.9, 2);
        let mka =
            MkaGp::fit(&base, &RbfKernel::new(1.0), 0.1, &config(12)).unwrap();
        let pol = ObservePolicy { drift_threshold: 1e-9, window: 40, ..ObservePolicy::default() };
        let (obs, rep) = mka.observed(&newer.x, &newer.y, &pol).unwrap();
        assert_eq!(rep.path, ObservePath::Refit);
        assert_eq!(rep.n_total, 40, "window caps the refit set");
        assert_eq!(obs.info().n, 40);
        // the newest points survive the window: last batch y values present
        let kept = &obs.train.y[40 - newer.n()..];
        assert_eq!(kept, &newer.y[..]);
    }

    /// A large batch under a tight core-growth budget trips gate 2.
    #[test]
    fn core_growth_gate_forces_refit() {
        let data = gp_dataset(&SynthSpec::named("t", 96, 2), 26);
        let (base, newer) = data.split(0.5, 3); // 48 old, 48 new
        let cfg = MkaConfig { d_core: 8, block_size: 24, ..MkaConfig::default() };
        let mka = MkaGp::fit(&base, &RbfKernel::new(1.0), 0.1, &cfg).unwrap();
        let pol = ObservePolicy { max_core_growth: 1.5, ..ObservePolicy::default() };
        let (_, rep) = mka.observed(&newer.x, &newer.y, &pol).unwrap();
        assert_eq!(rep.path, ObservePath::Refit);
        assert!(rep.reason.unwrap().contains("core growth"));
    }

    #[test]
    fn observe_rejects_malformed_batches() {
        let data = gp_dataset(&SynthSpec::named("t", 60, 2), 27);
        let mka = MkaGp::fit(&data, &RbfKernel::new(1.0), 0.1, &config(12)).unwrap();
        let pol = ObservePolicy::default();
        assert!(mka.observed(&Mat::zeros(0, 2), &[], &pol).is_err());
        assert!(mka.observed(&Mat::zeros(2, 2), &[1.0], &pol).is_err());
        assert!(mka.observed(&Mat::zeros(2, 3), &[1.0, 2.0], &pol).is_err());
        let mut bad = Mat::zeros(1, 2);
        bad.set(0, 0, f64::NAN);
        assert!(mka.observed(&bad, &[1.0], &pol).is_err());
        assert!(mka.observed(&Mat::zeros(1, 2), &[f64::INFINITY], &pol).is_err());
        let badpol = ObservePolicy { drift_threshold: 0.0, ..ObservePolicy::default() };
        assert!(mka.observed(&Mat::zeros(1, 2), &[1.0], &badpol).is_err());
        // trait hook surfaces the same path
        let up = mka
            .observe(&data.x.gather_rows(&[0]), &[data.y[0]], &pol)
            .expect("MKA supports observe")
            .unwrap();
        assert_eq!(up.report.str_field("path"), Some("incremental"));
        assert!(up.model.info().n == data.n() + 1);
    }

    #[test]
    fn refreshed_model_is_a_fresh_fit() {
        use crate::mka::factorize_count;
        let data = gp_dataset(&SynthSpec::named("t", 70, 2), 28);
        let mka = MkaGp::fit(&data, &RbfKernel::new(1.0), 0.1, &config(12)).unwrap();
        let before = factorize_count();
        let re = mka.refreshed_model().unwrap();
        assert!(factorize_count() > before, "refresh factorizes eagerly");
        let te = gp_dataset(&SynthSpec::named("q", 12, 2), 29);
        let p0 = mka.predict(&te.x);
        let p1 = re.predict(&te.x);
        for i in 0..te.n() {
            assert_eq!(p0.mean[i].to_bits(), p1.mean[i].to_bits());
        }
        // trait hook
        let boxed = mka.refreshed().expect("supported").unwrap();
        assert_eq!(boxed.info().n, data.n());
    }

    #[test]
    fn name_mentions_core() {
        let data = gp_dataset(&SynthSpec::named("t", 40, 2), 6);
        let mka = MkaGp::fit(&data, &RbfKernel::new(1.0), 0.1, &config(8)).unwrap();
        assert_eq!(mka.name(), "MKA(d=8)");
        assert_eq!(mka.d_core(), 8);
    }

    /// Repeat predicts against the same test set hit the joint-factor
    /// cache (instance miss counter pinned at 1 — each miss is exactly
    /// one joint factorization) and the served bits are identical to the
    /// cold path. Process-global `factorize_count` accounting lives in
    /// the dedicated tests/predict_cache.rs suite, where tests serialize.
    #[test]
    fn repeat_predict_hits_cache_bitwise() {
        let data = gp_dataset(&SynthSpec::named("t", 120, 2), 31);
        let (tr, te) = data.split(0.85, 8);
        let mka = MkaGp::fit(&tr, &RbfKernel::new(1.0), 0.1, &config(16)).unwrap();
        let cold = mka.predict(&te.x);
        assert_eq!(
            (mka.predict_cache().hits(), mka.predict_cache().misses()),
            (0, 1)
        );
        for round in 0..3 {
            let hot = mka.predict(&te.x);
            for i in 0..te.n() {
                assert_eq!(hot.mean[i].to_bits(), cold.mean[i].to_bits(), "mean[{i}] r{round}");
                assert_eq!(hot.var[i].to_bits(), cold.var[i].to_bits(), "var[{i}] r{round}");
            }
        }
        assert_eq!(
            (mka.predict_cache().hits(), mka.predict_cache().misses()),
            (3, 1),
            "repeat test sets must not refactorize"
        );
        // a different test set misses (and does not disturb the old entry)
        let te2 = gp_dataset(&SynthSpec::named("q", 10, 2), 32);
        let _ = mka.predict(&te2.x);
        assert_eq!(mka.predict_cache().misses(), 2);
        let _ = mka.predict(&te.x);
        assert_eq!(mka.predict_cache().hits(), 4);
    }

    /// `retuned` shares the predict cache: after a σ²-only retune the
    /// first predict against a warm test set is already a hit, and its
    /// bits equal a fresh fit at the new σ² — the cached noise-free
    /// factor plus `shifted` IS the cold path.
    #[test]
    fn retune_keeps_predict_cache_hot() {
        let data = gp_dataset(&SynthSpec::named("t", 110, 2), 33);
        let (tr, te) = data.split(0.85, 9);
        let kern = RbfKernel::new(1.0);
        let mka = MkaGp::fit(&tr, &kern, 0.1, &config(16)).unwrap();
        let _ = mka.predict(&te.x); // warm the cache at σ²=0.1
        let re = mka.retuned(0.3).unwrap();
        let hits_before = re.predict_cache().hits();
        let pr = re.predict(&te.x);
        assert_eq!(re.predict_cache().hits(), hits_before + 1, "retune must not invalidate");
        let fresh = MkaGp::fit(&tr, &kern, 0.3, &config(16)).unwrap();
        let pf = fresh.predict(&te.x);
        for i in 0..te.n() {
            assert_eq!(pr.mean[i].to_bits(), pf.mean[i].to_bits(), "mean[{i}]");
            assert_eq!(pr.var[i].to_bits(), pf.var[i].to_bits(), "var[{i}]");
        }
    }

    /// `observed` changes the training set, so the updated model starts
    /// with a fresh, empty cache — while the pre-update model keeps its
    /// entries (the sharded fleet's untouched shards ride exactly this).
    #[test]
    fn observe_gets_a_fresh_cache() {
        let data = gp_dataset(&SynthSpec::named("t", 100, 2), 34);
        let (base, newer) = data.split(0.9, 4);
        let te = gp_dataset(&SynthSpec::named("q", 12, 2), 35);
        let mka = MkaGp::fit(&base, &RbfKernel::new(1.0), 0.1, &config(12)).unwrap();
        let _ = mka.predict(&te.x);
        assert_eq!(mka.predict_cache().len(), 1);
        let (obs, _) = mka
            .observed(&newer.x, &newer.y, &ObservePolicy::default())
            .unwrap();
        assert_eq!(obs.predict_cache().len(), 0, "stale entries must not survive observe");
        assert_eq!(mka.predict_cache().len(), 1, "the old model keeps its entries");
        // the updated model's first predict is a miss, then hits
        let _ = obs.predict(&te.x);
        let _ = obs.predict(&te.x);
        assert_eq!((obs.predict_cache().hits(), obs.predict_cache().misses()), (1, 1));
    }

    /// Tiled joint assembly (memoized train gram + fresh cross/test
    /// tiles) must be bit-identical to the full joint rebuild: force the
    /// train factor (which memoizes the train gram) on one model, leave
    /// the other cold, and compare predict bits.
    #[test]
    fn tiled_joint_assembly_matches_full_rebuild_bitwise() {
        let data = gp_dataset(&SynthSpec::named("t", 130, 2), 36);
        let (tr, te) = data.split(0.85, 10);
        let kern = RbfKernel::new(0.9);
        let tiled = MkaGp::fit(&tr, &kern, 0.1, &config(16)).unwrap();
        tiled.train_factor().unwrap(); // memoizes the n×n train gram
        assert!(tiled.train_gram.get().is_some());
        let full = MkaGp::fit(&tr, &kern, 0.1, &config(16)).unwrap();
        assert!(full.train_gram.get().is_none());
        let pt = tiled.predict(&te.x);
        let pf = full.predict(&te.x);
        for i in 0..te.n() {
            assert_eq!(pt.mean[i].to_bits(), pf.mean[i].to_bits(), "mean[{i}]");
            assert_eq!(pt.var[i].to_bits(), pf.var[i].to_bits(), "var[{i}]");
        }
        // the cold model memoized its train gram off the joint assembly
        assert!(full.train_gram.get().is_some());
    }

    /// `diagnose` carries the predict-cache section, and reading it
    /// never builds anything.
    #[test]
    fn diagnose_reports_predict_cache() {
        let data = gp_dataset(&SynthSpec::named("t", 60, 2), 37);
        let (tr, te) = data.split(0.8, 11);
        let mka = MkaGp::fit(&tr, &RbfKernel::new(1.0), 0.1, &config(12)).unwrap();
        let d = mka.diagnose().unwrap();
        let pc = d.get("predict_cache").expect("section present");
        assert_eq!(pc.num_field("entries"), Some(0.0));
        assert_eq!(pc.num_field("misses"), Some(0.0));
        let _ = mka.predict(&te.x);
        let _ = mka.predict(&te.x);
        let pc = mka.diagnose().unwrap();
        let pc = pc.get("predict_cache").unwrap();
        assert_eq!(pc.num_field("entries"), Some(1.0));
        assert_eq!(pc.num_field("hits"), Some(1.0));
        assert_eq!(pc.num_field("misses"), Some(1.0));
        assert_eq!(pc.num_field("evictions"), Some(0.0));
    }
}
