//! Exact GP regression via Cholesky factorization — the paper's "Full"
//! reference method (Rasmussen & Williams 2005, Alg. 2.1).
//!
//! O(n³) fit, O(n²) per-point predictive variance; only tractable for the
//! small-to-mid datasets, which is the whole point of the paper.

use super::{GpModel, ModelInfo, Prediction};
use crate::data::dataset::Dataset;
use crate::error::Result;
use crate::kernels::Kernel;
use crate::la::blas::dot;
use crate::la::chol::{solve_lower, Chol};
use crate::la::dense::Mat;

/// Exact GP posterior.
pub struct FullGp {
    x_train: Mat,
    kernel: Box<dyn Kernel>,
    sigma2: f64,
    /// α = (K + σ²I)⁻¹ y.
    alpha: Vec<f64>,
    /// Cholesky of K + σ²I (for predictive variance).
    chol: Chol,
}

impl FullGp {
    /// Fit on a training set: one Cholesky of K + σ²I.
    pub fn fit(train: &Dataset, kernel: &dyn Kernel, sigma2: f64) -> Result<FullGp> {
        let mut k = kernel.gram_sym(&train.x);
        k.add_diag(sigma2);
        let (chol, _jitter) = Chol::new_jittered(&k, 12)?;
        let alpha = chol.solve(&train.y);
        Ok(FullGp {
            x_train: train.x.clone(),
            kernel: kernel.boxed_clone(),
            sigma2,
            alpha,
            chol,
        })
    }

    /// Log marginal likelihood of the training targets (for reference and
    /// hyperparameter diagnostics): −½ yᵀα − Σ log L_ii − (n/2) log 2π.
    pub fn log_marginal(&self, y: &[f64]) -> f64 {
        let n = y.len() as f64;
        -0.5 * dot(y, &self.alpha) - 0.5 * self.chol.logdet()
            - 0.5 * n * (2.0 * std::f64::consts::PI).ln()
    }

    pub fn sigma2(&self) -> f64 {
        self.sigma2
    }
}

impl GpModel for FullGp {
    fn predict(&self, x_test: &Mat) -> Prediction {
        let p = x_test.rows;
        let mut mean = Vec::with_capacity(p);
        let mut var = Vec::with_capacity(p);
        for t in 0..p {
            let xt = x_test.row(t);
            let kx = self.kernel.cross(xt, &self.x_train);
            mean.push(dot(&kx, &self.alpha));
            // v = L⁻¹ kx ; var = k** − vᵀv + σ²
            let v = solve_lower(&self.chol.l, &kx);
            let kss = self.kernel.diag(xt);
            var.push((kss - dot(&v, &v)).max(0.0) + self.sigma2);
        }
        Prediction { mean, var }
    }

    fn name(&self) -> String {
        "Full".to_string()
    }

    fn info(&self) -> ModelInfo {
        ModelInfo {
            method: self.name(),
            n: self.x_train.rows,
            dim: self.x_train.cols,
            sigma2: Some(self.sigma2),
            shards: 1,
            shard_sizes: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::gp::metrics::{mnlp, smse};
    use crate::kernels::RbfKernel;
    use crate::la::dense::Mat;

    fn small_data() -> Dataset {
        gp_dataset(&SynthSpec::named("t", 120, 2), 1)
    }

    #[test]
    fn interpolates_training_data_at_low_noise() {
        let d = small_data();
        let k = RbfKernel::new(1.0);
        let gp = FullGp::fit(&d, &k, 1e-6).unwrap();
        let pred = gp.predict(&d.x);
        let e = smse(&d.y, &pred.mean);
        assert!(e < 0.05, "training SMSE {e}");
    }

    #[test]
    fn beats_mean_predictor_on_test() {
        let d = small_data();
        let (tr, te) = d.split(0.8, 2);
        let gp = FullGp::fit(&tr, &RbfKernel::new(1.0), 0.05).unwrap();
        let pred = gp.predict(&te.x);
        let e = smse(&te.y, &pred.mean);
        assert!(e < 0.9, "test SMSE {e}");
        let nl = mnlp(&te.y, &pred.mean, &pred.var);
        assert!(nl.is_finite());
    }

    #[test]
    fn variance_grows_away_from_data() {
        let x = Mat::from_vec(5, 1, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        let y = vec![0.0, 1.0, 0.0, -1.0, 0.0];
        let d = Dataset::new("line", x, y);
        let gp = FullGp::fit(&d, &RbfKernel::new(0.5), 0.01).unwrap();
        let near = gp.predict(&Mat::from_vec(1, 1, vec![2.0]));
        let far = gp.predict(&Mat::from_vec(1, 1, vec![40.0]));
        assert!(far.var[0] > near.var[0]);
        // far from data: var → k** + σ²
        assert!((far.var[0] - 1.01).abs() < 1e-6);
    }

    #[test]
    fn variance_at_least_noise() {
        let d = small_data();
        let gp = FullGp::fit(&d, &RbfKernel::new(1.0), 0.3).unwrap();
        let pred = gp.predict(&d.x);
        for v in pred.var {
            assert!(v >= 0.3 - 1e-12);
        }
    }

    #[test]
    fn log_marginal_finite_and_reasonable() {
        let d = small_data();
        let gp = FullGp::fit(&d, &RbfKernel::new(1.0), 0.1).unwrap();
        let lml = gp.log_marginal(&d.y);
        assert!(lml.is_finite());
        assert!(lml < 0.0); // normalized data
    }
}
