//! Gaussian process regression models.
//!
//! * [`full::FullGp`] — exact GP via Cholesky (the paper's "Full" column);
//! * [`mka_gp::MkaGp`] — the paper's method (§4.1): MKA of the joint
//!   train/test kernel + Schur complement;
//! * [`ridge::MkaRidge`] — kernel ridge regression through an MKA solve
//!   (the frequentist cousin, mean only);
//! * [`cv`] — k-fold cross-validation for hyperparameters (§5 protocol),
//!   plus the hyperparameter types themselves: [`cv::HyperParams`]
//!   (isotropic ℓ, σ²) and [`cv::ArdHyperParams`] (per-dimension ℓ_d —
//!   the ARD parametrization the gradient trainer optimizes);
//! * [`metrics`] — SMSE / MNLP.
//!
//! The five sparse baselines live in [`crate::baselines`] and implement the
//! same [`GpModel`] trait.

pub mod cv;
pub mod full;
pub mod metrics;
pub mod mka_gp;
pub mod ridge;

use crate::la::dense::Mat;

/// Posterior prediction: mean and (predictive, noise-inclusive) variance
/// per test point.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub mean: Vec<f64>,
    pub var: Vec<f64>,
}

impl Prediction {
    pub fn len(&self) -> usize {
        self.mean.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mean.is_empty()
    }
}

/// A fitted GP regression model.
pub trait GpModel: Send + Sync {
    /// Predict mean and variance at the rows of `x_test`.
    fn predict(&self, x_test: &Mat) -> Prediction;

    /// Model name for tables/logs.
    fn name(&self) -> String;

    /// Cheap σ² re-tune: a copy of this model serving at noise variance
    /// `sigma2` **without refitting**, when the method supports it. For
    /// MKA, noise is a spectrum shift of the stored factorization
    /// ([`crate::mka::MkaFactor::shifted`]), so this is O(1) work plus a
    /// registry republish — the serving-plane `retune` op rides it.
    /// `None` means unsupported (or an invalid σ²); callers fall back to
    /// a full refit job.
    fn with_noise(&self, _sigma2: f64) -> Option<Box<dyn GpModel>> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prediction_len() {
        let p = Prediction { mean: vec![1.0, 2.0], var: vec![0.1, 0.2] };
        assert_eq!(p.len(), 2);
        assert!(!p.is_empty());
    }
}
