//! Gaussian process regression models.
//!
//! * [`full::FullGp`] — exact GP via Cholesky (the paper's "Full" column);
//! * [`mka_gp::MkaGp`] — the paper's method (§4.1): MKA of the joint
//!   train/test kernel + Schur complement;
//! * [`ridge::MkaRidge`] — kernel ridge regression through an MKA solve
//!   (the frequentist cousin, mean only);
//! * [`cv`] — k-fold cross-validation for hyperparameters (§5 protocol),
//!   plus the hyperparameter types themselves: [`cv::HyperParams`]
//!   (isotropic ℓ, σ²) and [`cv::ArdHyperParams`] (per-dimension ℓ_d —
//!   the ARD parametrization the gradient trainer optimizes);
//! * [`metrics`] — SMSE / MNLP.
//!
//! The five sparse baselines live in [`crate::baselines`] and implement the
//! same [`GpModel`] trait.

pub mod cv;
pub mod full;
pub mod metrics;
pub mod mka_gp;
pub mod ridge;
pub mod sharded;

use crate::la::dense::Mat;
use crate::util::json::Json;

/// Posterior prediction: mean and (predictive, noise-inclusive) variance
/// per test point.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub mean: Vec<f64>,
    pub var: Vec<f64>,
}

impl Prediction {
    pub fn len(&self) -> usize {
        self.mean.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mean.is_empty()
    }
}

/// Descriptive metadata for a fitted model — what the serving plane's
/// `models` op reports per registry entry. `shards == 1` with an empty
/// `shard_sizes` is the unsharded case.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// Method label, same vocabulary as [`GpModel::name`].
    pub method: String,
    /// Training-set size (0 when the model does not retain it).
    pub n: usize,
    /// Input dimension (0 when the model does not retain it).
    pub dim: usize,
    /// Observation-noise variance, when the model exposes one.
    pub sigma2: Option<f64>,
    /// Number of shards behind this model (1 unless sharded).
    pub shards: usize,
    /// Per-shard training sizes in shard-id order (empty when unsharded).
    pub shard_sizes: Vec<usize>,
}

impl ModelInfo {
    /// Name-only metadata — the default for models that retain nothing
    /// beyond their label.
    pub fn basic(method: String) -> ModelInfo {
        ModelInfo {
            method,
            n: 0,
            dim: 0,
            sigma2: None,
            shards: 1,
            shard_sizes: Vec::new(),
        }
    }
}

/// A fitted GP regression model.
pub trait GpModel: Send + Sync {
    /// Predict mean and variance at the rows of `x_test`.
    fn predict(&self, x_test: &Mat) -> Prediction;

    /// Model name for tables/logs.
    fn name(&self) -> String;

    /// Cheap σ² re-tune: a copy of this model serving at noise variance
    /// `sigma2` **without refitting**, when the method supports it. For
    /// MKA, noise is a spectrum shift of the stored factorization
    /// ([`crate::mka::MkaFactor::shifted`]), so this is O(1) work plus a
    /// registry republish — the serving-plane `retune` op rides it.
    /// `None` means unsupported (or an invalid σ²); callers fall back to
    /// a full refit job.
    fn with_noise(&self, _sigma2: f64) -> Option<Box<dyn GpModel>> {
        None
    }

    /// Descriptive metadata (method, training shape, σ², shard topology)
    /// for the serving plane's `models` op. The default reports the name
    /// only; models that retain their training set override it.
    fn info(&self) -> ModelInfo {
        ModelInfo::basic(self.name())
    }

    /// Structured numerical-health diagnostics — the payload behind the
    /// serving plane's `diagnose` op. Implementations must report from
    /// **already-held** state only (per-stage compression, shifted
    /// spectrum extremes, counters): never fit, refit or refactorize.
    /// `None` means the method has nothing to report (the default).
    fn diagnose(&self) -> Option<Json> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prediction_len() {
        let p = Prediction { mean: vec![1.0, 2.0], var: vec![0.1, 0.2] };
        assert_eq!(p.len(), 2);
        assert!(!p.is_empty());
    }
}
