//! Gaussian process regression models.
//!
//! * [`full::FullGp`] — exact GP via Cholesky (the paper's "Full" column);
//! * [`mka_gp::MkaGp`] — the paper's method (§4.1): MKA of the joint
//!   train/test kernel + Schur complement;
//! * [`ridge::MkaRidge`] — kernel ridge regression through an MKA solve
//!   (the frequentist cousin, mean only);
//! * [`cv`] — k-fold cross-validation for hyperparameters (§5 protocol),
//!   plus the hyperparameter types themselves: [`cv::HyperParams`]
//!   (isotropic ℓ, σ²) and [`cv::ArdHyperParams`] (per-dimension ℓ_d —
//!   the ARD parametrization the gradient trainer optimizes);
//! * [`metrics`] — SMSE / MNLP.
//!
//! The five sparse baselines live in [`crate::baselines`] and implement the
//! same [`GpModel`] trait.

pub mod cv;
pub mod full;
pub mod metrics;
pub mod mka_gp;
pub mod predict_cache;
pub mod ridge;
pub mod sharded;

use crate::error::{Error, Result};
use crate::la::dense::Mat;
use crate::mka::ExtendStats;
use crate::util::json::Json;

/// Posterior prediction: mean and (predictive, noise-inclusive) variance
/// per test point.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub mean: Vec<f64>,
    pub var: Vec<f64>,
}

impl Prediction {
    pub fn len(&self) -> usize {
        self.mean.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mean.is_empty()
    }
}

/// Descriptive metadata for a fitted model — what the serving plane's
/// `models` op reports per registry entry. `shards == 1` with an empty
/// `shard_sizes` is the unsharded case.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// Method label, same vocabulary as [`GpModel::name`].
    pub method: String,
    /// Training-set size (0 when the model does not retain it).
    pub n: usize,
    /// Input dimension (0 when the model does not retain it).
    pub dim: usize,
    /// Observation-noise variance, when the model exposes one.
    pub sigma2: Option<f64>,
    /// Number of shards behind this model (1 unless sharded).
    pub shards: usize,
    /// Per-shard training sizes in shard-id order (empty when unsharded).
    pub shard_sizes: Vec<usize>,
}

impl ModelInfo {
    /// Name-only metadata — the default for models that retain nothing
    /// beyond their label.
    pub fn basic(method: String) -> ModelInfo {
        ModelInfo {
            method,
            n: 0,
            dim: 0,
            sigma2: None,
            shards: 1,
            shard_sizes: Vec::new(),
        }
    }
}

/// When the streaming observe path abandons the incremental factor
/// extension and falls back to a windowed full re-fit.
#[derive(Clone, Debug)]
pub struct ObservePolicy {
    /// Predictive-drift gate: refit when the mean standardized squared
    /// residual of the current model's predictions on the incoming batch
    /// — mean((y − μ̂)²/σ̂²), ≈ 1 when calibrated — exceeds this.
    pub drift_threshold: f64,
    /// Compression-degradation gate: refit when the extended factor's
    /// final core has grown past `max_core_growth × d_core`.
    pub max_core_growth: f64,
    /// Refit window: keep only the most recent `window` training points
    /// on the refit path (`0` = keep everything).
    pub window: usize,
}

impl Default for ObservePolicy {
    fn default() -> Self {
        ObservePolicy { drift_threshold: 16.0, max_core_growth: 4.0, window: 0 }
    }
}

impl ObservePolicy {
    pub fn validate(&self) -> Result<()> {
        if !(self.drift_threshold.is_finite() && self.drift_threshold > 0.0) {
            return Err(Error::Config(format!(
                "observe: drift_threshold must be finite and > 0, got {}",
                self.drift_threshold
            )));
        }
        if !(self.max_core_growth.is_finite() && self.max_core_growth >= 1.0) {
            return Err(Error::Config(format!(
                "observe: max_core_growth must be finite and >= 1, got {}",
                self.max_core_growth
            )));
        }
        Ok(())
    }
}

/// Which route one observe call took.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObservePath {
    /// The existing factor was extended in place (stages reused).
    Incremental,
    /// A drift gate fired and forced a windowed full re-fit.
    Refit,
}

impl ObservePath {
    pub fn as_str(&self) -> &'static str {
        match self {
            ObservePath::Incremental => "incremental",
            ObservePath::Refit => "refit",
        }
    }
}

/// What one observe call did — the exact record behind the coordinator's
/// `observe` response and the equivalence tests' assertions.
#[derive(Clone, Debug)]
pub struct ObserveReport {
    /// Incremental extension or gated refit.
    pub path: ObservePath,
    /// Why the drift gate fired (refit path only).
    pub reason: Option<String>,
    /// Points appended by this call.
    pub appended: usize,
    /// Training-set size after the update.
    pub n_total: usize,
    /// Mean standardized squared residual of the pre-update model on the
    /// incoming batch (the drift-gate statistic).
    pub drift: f64,
    /// Stage accounting of the incremental extension (None on refit).
    pub stats: Option<ExtendStats>,
}

impl ObserveReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("path", Json::Str(self.path.as_str().into()))
            .with("appended", Json::Num(self.appended as f64))
            .with("n_total", Json::Num(self.n_total as f64))
            .with("drift", Json::Num(self.drift));
        if let Some(r) = &self.reason {
            j = j.with("reason", Json::Str(r.clone()));
        }
        if let Some(s) = &self.stats {
            j = j
                .with("stages_total", Json::Num(s.stages_total as f64))
                .with("stages_rebuilt", Json::Num(s.stages_rebuilt as f64))
                .with("stages_reused", Json::Num(s.stages_reused as f64))
                .with("blocks_reused", Json::Num(s.blocks_reused as f64))
                .with("blocks_touched", Json::Num(s.blocks_touched as f64))
                .with("core_growth", Json::Num(s.core_growth as f64));
        }
        j
    }
}

/// An updated model plus the structured record of how it was produced —
/// what [`GpModel::observe`] hands the serving plane to republish.
pub struct ObserveUpdate {
    pub model: Box<dyn GpModel>,
    pub report: Json,
}

/// A fitted GP regression model.
pub trait GpModel: Send + Sync {
    /// Predict mean and variance at the rows of `x_test`.
    fn predict(&self, x_test: &Mat) -> Prediction;

    /// Model name for tables/logs.
    fn name(&self) -> String;

    /// Cheap σ² re-tune: a copy of this model serving at noise variance
    /// `sigma2` **without refitting**, when the method supports it. For
    /// MKA, noise is a spectrum shift of the stored factorization
    /// ([`crate::mka::MkaFactor::shifted`]), so this is O(1) work plus a
    /// registry republish — the serving-plane `retune` op rides it.
    /// `None` means unsupported (or an invalid σ²); callers fall back to
    /// a full refit job.
    fn with_noise(&self, _sigma2: f64) -> Option<Box<dyn GpModel>> {
        None
    }

    /// Descriptive metadata (method, training shape, σ², shard topology)
    /// for the serving plane's `models` op. The default reports the name
    /// only; models that retain their training set override it.
    fn info(&self) -> ModelInfo {
        ModelInfo::basic(self.name())
    }

    /// Structured numerical-health diagnostics — the payload behind the
    /// serving plane's `diagnose` op. Implementations must report from
    /// **already-held** state only (per-stage compression, shifted
    /// spectrum extremes, counters): never fit, refit or refactorize.
    /// `None` means the method has nothing to report (the default).
    fn diagnose(&self) -> Option<Json> {
        None
    }

    /// Streaming update: append the batch `(x, y)` and return the updated
    /// model plus a structured report of which path (incremental extension
    /// vs gated windowed refit) was taken. `None` means the method does not
    /// support streaming observation (the default) — the serving plane
    /// reports a typed error instead of silently refitting.
    fn observe(
        &self,
        _x: &Mat,
        _y: &[f64],
        _policy: &ObservePolicy,
    ) -> Option<Result<ObserveUpdate>> {
        None
    }

    /// Cheap capability probe for [`GpModel::refreshed`] — lets the
    /// serving plane reject a refresh policy synchronously without
    /// running (and discarding) an actual refit.
    fn can_refresh(&self) -> bool {
        false
    }

    /// Background refresh: a from-scratch refit of this model on its
    /// currently-held training set, for the recurring refresh scheduler.
    /// `None` means unsupported (the default).
    fn refreshed(&self) -> Option<Result<Box<dyn GpModel>>> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prediction_len() {
        let p = Prediction { mean: vec![1.0, 2.0], var: vec![0.1, 0.2] };
        assert_eq!(p.len(), 2);
        assert!(!p.is_empty());
    }
}
