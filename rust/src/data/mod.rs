//! Dataset substrate: container/splits ([`dataset`]), synthetic generators
//! matching the paper's evaluation suite ([`synth`]), CSV IO ([`loader`]).

pub mod dataset;
pub mod loader;
pub mod synth;

pub use dataset::Dataset;
pub use synth::SynthSpec;
