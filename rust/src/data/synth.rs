//! Synthetic dataset generation.
//!
//! The paper evaluates on six small UCI/MAP regression datasets (Table 1 of
//! the supplement). Those files cannot be downloaded in this offline build,
//! so — per the documented substitution in DESIGN.md §5 — we generate
//! datasets with **identical sizes and dimensionalities** whose targets are
//! sampled from a Gaussian process with a *mixture of long and short length
//! scales*. That is precisely the broad-spectrum regime the paper's
//! argument is about: the short-length-scale component creates the heavy
//! eigenvalue tail that defeats global low-rank (Nyström-family) methods,
//! while the long component carries PCA-like global structure.
//!
//! GP sampling uses random Fourier features (Rahimi & Recht 2008): an RBF
//! GP prior draw is approximated by `f(x) = Σ_k w_k √(2/m) cos(ω_k·x+b_k)`
//! with `ω ~ N(0, I/ℓ²)`, `w ~ N(0, 1)` — O(n·m) instead of O(n³), exact in
//! distribution as m → ∞. Features come from anisotropic Gaussian clusters
//! so stage-1 clustering has real structure to find.

use super::dataset::Dataset;
use crate::la::dense::Mat;
use crate::util::Rng;

/// Specification of a synthetic regression dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: String,
    /// Number of points.
    pub n: usize,
    /// Feature dimension.
    pub d: usize,
    /// Number of feature clusters.
    pub n_clusters: usize,
    /// Long (global) target length scale.
    pub ell_global: f64,
    /// Short (local) target length scale.
    pub ell_local: f64,
    /// Weight of the local component in the target mix (0..1).
    pub local_weight: f64,
    /// Observation noise std.
    pub noise: f64,
}

impl SynthSpec {
    /// A reasonable default broad-spectrum spec.
    pub fn named(name: &str, n: usize, d: usize) -> SynthSpec {
        SynthSpec {
            name: name.to_string(),
            n,
            d,
            n_clusters: (n / 256).clamp(2, 24),
            ell_global: 4.0,
            ell_local: 0.5,
            local_weight: 0.45,
            noise: 0.1,
        }
    }
}

/// Number of random Fourier features used by the GP sampler.
const RFF_FEATURES: usize = 512;

/// Latent (intrinsic) dimension of generated feature manifolds. Real
/// tabular datasets have strongly correlated columns — their intrinsic
/// dimension is far below the ambient one — and GP regression is only
/// meaningful in that regime (in a full-rank 13-D Gaussian cloud all
/// pairwise distances concentrate and nothing is learnable). We therefore
/// sample cluster-structured points on a low-dimensional manifold and
/// embed them linearly into the ambient dimension (plus small ambient
/// noise), which mirrors the UCI datasets' correlation structure.
const LATENT_DIM: usize = 3;

/// Draw feature matrix: `n_clusters` anisotropic Gaussian blobs in d dims.
pub fn clustered_features(n: usize, d: usize, n_clusters: usize, rng: &mut Rng) -> Mat {
    let k = n_clusters.clamp(1, n);
    // cluster centers spread out; per-cluster axis scales in [0.3, 1.2]
    let centers = Mat::from_fn(k, d, |_, _| 3.0 * rng.normal());
    let scales = Mat::from_fn(k, d, |_, _| rng.uniform_in(0.3, 1.2));
    Mat::from_fn(n, d, |i, j| {
        let c = i % k; // deterministic round-robin keeps clusters balanced
        centers.at(c, j) + scales.at(c, j) * rng.normal()
    })
}

/// Approximate RBF-GP prior draw over the rows of `x` via random Fourier
/// features with length scale `ell`.
pub fn gp_prior_draw(x: &Mat, ell: f64, rng: &mut Rng) -> Vec<f64> {
    let m = RFF_FEATURES;
    let d = x.cols;
    // ω ~ N(0, I/ℓ²), b ~ U[0, 2π), w ~ N(0, 1)
    let omega = Mat::from_fn(m, d, |_, _| rng.normal() / ell);
    let b: Vec<f64> = (0..m).map(|_| rng.uniform_in(0.0, std::f64::consts::TAU)).collect();
    let w: Vec<f64> = rng.normal_vec(m);
    let scale = (2.0 / m as f64).sqrt();
    (0..x.rows)
        .map(|i| {
            let xi = x.row(i);
            let mut s = 0.0;
            for k in 0..m {
                let phase = crate::la::blas::dot(omega.row(k), xi) + b[k];
                s += w[k] * phase.cos();
            }
            s * scale
        })
        .collect()
}

/// Embed latent clustered features into `d` ambient dimensions through a
/// random linear map plus small ambient noise.
pub fn latent_features(n: usize, d: usize, n_clusters: usize, rng: &mut Rng) -> Mat {
    let dl = LATENT_DIM.min(d);
    let z = clustered_features(n, dl, n_clusters, rng);
    if dl == d {
        return z;
    }
    // Random embedding with roughly orthonormal rows.
    let w = Mat::from_fn(dl, d, |_, _| rng.normal() / (dl as f64).sqrt());
    let mut x = crate::la::blas::gemm(&z, &w);
    for v in &mut x.data {
        *v += 0.05 * rng.normal();
    }
    x
}

/// Generate a dataset from a spec (deterministic given the seed).
pub fn gp_dataset(spec: &SynthSpec, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x6d6b_6130);
    let x = latent_features(spec.n, spec.d, spec.n_clusters, &mut rng);
    let f_global = gp_prior_draw(&x, spec.ell_global, &mut rng);
    let f_local = gp_prior_draw(&x, spec.ell_local, &mut rng);
    let wl = spec.local_weight;
    let y: Vec<f64> = (0..spec.n)
        .map(|i| {
            (1.0 - wl) * f_global[i] + wl * f_local[i] + spec.noise * rng.normal()
        })
        .collect();
    let mut ds = Dataset::new(spec.name.clone(), x, y);
    ds.normalize();
    ds
}

/// The six Table-1 dataset stand-ins: identical (n, d) to the paper's
/// supplement Table 1, broad-spectrum targets per DESIGN.md §5.
pub fn table1_specs() -> Vec<SynthSpec> {
    vec![
        SynthSpec::named("housing", 506, 13),
        SynthSpec::named("rupture", 2066, 30),
        SynthSpec::named("wine", 4898, 11),
        SynthSpec::named("pageblocks", 5473, 10),
        SynthSpec::named("compAct", 8192, 21),
        SynthSpec::named("pendigit", 10992, 16),
    ]
}

/// Per-dataset `k` (number of pseudo-inputs / d_core) used in Table 1.
pub fn table1_k(name: &str) -> usize {
    match name {
        "housing" | "rupture" => 16,
        "wine" | "pageblocks" | "compAct" => 32,
        "pendigit" => 64,
        _ => 32,
    }
}

/// Snelson-style 1D toy (Figure 1): inputs on [0, 6] with a gap, targets
/// drawn from a GP with length scale 0.5 (exactly the paper's protocol:
/// "We sampled the ground truth from a Gaussian process with length scale
/// ℓ = 0.5").
pub fn snelson1d(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x536e_656c);
    // Leave a gap in the middle like the classic Snelson data, so the
    // posterior-uncertainty behaviour in the gap is visible.
    let mut xs: Vec<f64> = Vec::with_capacity(n);
    while xs.len() < n {
        let x = rng.uniform_in(0.0, 6.0);
        if !(2.6..3.4).contains(&x) {
            xs.push(x);
        }
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let x = Mat::from_vec(n, 1, xs);
    let f = gp_prior_draw(&x, 0.5, &mut rng);
    let y: Vec<f64> = f.iter().map(|&v| v + 0.1 * rng.normal()).collect();
    Dataset::new("snelson1d", x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Kernel, RbfKernel};
    use crate::la::stats::{mean, std_dev, variance};

    #[test]
    fn spec_catalog_matches_paper_sizes() {
        let specs = table1_specs();
        let expected = [
            ("housing", 506, 13),
            ("rupture", 2066, 30),
            ("wine", 4898, 11),
            ("pageblocks", 5473, 10),
            ("compAct", 8192, 21),
            ("pendigit", 10992, 16),
        ];
        assert_eq!(specs.len(), 6);
        for (s, (name, n, d)) in specs.iter().zip(expected) {
            assert_eq!(s.name, name);
            assert_eq!(s.n, n);
            assert_eq!(s.d, d);
        }
        assert_eq!(table1_k("housing"), 16);
        assert_eq!(table1_k("pendigit"), 64);
    }

    #[test]
    fn dataset_generation_is_deterministic_and_normalized() {
        let spec = SynthSpec::named("t", 300, 5);
        let a = gp_dataset(&spec, 9);
        let b = gp_dataset(&spec, 9);
        assert_eq!(a.y, b.y);
        assert!(mean(&a.y).abs() < 1e-10);
        assert!((std_dev(&a.y) - 1.0).abs() < 1e-10);
        let c = gp_dataset(&spec, 10);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn rff_draw_has_unit_scale() {
        let mut rng = Rng::new(4);
        let x = Mat::from_fn(400, 3, |_, _| rng.normal());
        let f = gp_prior_draw(&x, 1.0, &mut rng);
        // marginal variance of an RBF GP draw is 1; RFF approximates it
        let v = variance(&f);
        assert!((0.4..1.8).contains(&v), "var={v}");
    }

    #[test]
    fn rff_matches_kernel_correlation() {
        // Two nearby points must be highly correlated across draws.
        let x = Mat::from_rows(&[&[0.0], &[0.1], &[5.0]]);
        let kern = RbfKernel::new(1.0);
        let k01 = kern.eval(x.row(0), x.row(1));
        let mut c01 = 0.0;
        let mut c02 = 0.0;
        let reps = 200;
        let mut rng = Rng::new(5);
        for _ in 0..reps {
            let f = gp_prior_draw(&x, 1.0, &mut rng);
            c01 += f[0] * f[1];
            c02 += f[0] * f[2];
        }
        c01 /= reps as f64;
        c02 /= reps as f64;
        assert!((c01 - k01).abs() < 0.15, "c01={c01} vs k={k01}");
        assert!(c02.abs() < 0.15, "c02={c02}");
    }

    #[test]
    fn snelson_has_gap_and_sorted_inputs() {
        let d = snelson1d(200, 1);
        assert_eq!(d.n(), 200);
        for i in 1..200 {
            assert!(d.x.at(i, 0) >= d.x.at(i - 1, 0));
            assert!(!(2.6..3.4).contains(&d.x.at(i, 0)));
        }
    }

    #[test]
    fn clustered_features_balanced() {
        let mut rng = Rng::new(6);
        let x = clustered_features(100, 4, 5, &mut rng);
        assert_eq!(x.rows, 100);
        assert_eq!(x.cols, 4);
    }
}
