//! CSV loading / saving for datasets and experiment outputs.
//!
//! If a user drops real UCI CSVs into `data/` the loaders here pick them up
//! (last column = target); otherwise the synthetic catalog in
//! [`super::synth`] is used. Writers produce the CSV series behind the
//! paper's figures.

use std::io::Write;
use std::path::Path;

use super::dataset::Dataset;
use crate::error::{Error, Result};
use crate::la::dense::Mat;

/// Load a numeric CSV where the last column is the regression target.
/// Lines starting with '#' and a non-numeric header row are skipped.
pub fn load_csv(path: &Path, name: &str) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parsed: Option<Vec<f64>> =
            line.split(',').map(|t| t.trim().parse::<f64>().ok()).collect();
        match parsed {
            Some(vals) if !vals.is_empty() => {
                if let Some(first) = rows.first() {
                    if vals.len() != first.len() {
                        return Err(Error::Data(format!(
                            "{}: ragged row at line {}",
                            path.display(),
                            lineno + 1
                        )));
                    }
                }
                rows.push(vals);
            }
            // header or junk row: only acceptable as the first content line
            _ if rows.is_empty() => continue,
            _ => {
                return Err(Error::Data(format!(
                    "{}: non-numeric row at line {}",
                    path.display(),
                    lineno + 1
                )))
            }
        }
    }
    if rows.is_empty() {
        return Err(Error::Data(format!("{}: no data rows", path.display())));
    }
    let d = rows[0].len();
    if d < 2 {
        return Err(Error::Data("need at least one feature and one target column".into()));
    }
    let n = rows.len();
    let mut x = Mat::zeros(n, d - 1);
    let mut y = Vec::with_capacity(n);
    for (i, row) in rows.iter().enumerate() {
        x.row_mut(i).copy_from_slice(&row[..d - 1]);
        y.push(row[d - 1]);
    }
    Ok(Dataset::new(name, x, y))
}

/// Save (x, y) as CSV.
pub fn save_csv(path: &Path, ds: &Dataset) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    for i in 0..ds.n() {
        let mut line = String::new();
        for v in ds.x.row(i) {
            line.push_str(&format!("{v},"));
        }
        line.push_str(&format!("{}\n", ds.y[i]));
        f.write_all(line.as_bytes())?;
    }
    Ok(())
}

/// Write a generic CSV table with a header (figure/bench series output).
pub fn write_table(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let line: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("mka_gp_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn csv_roundtrip() {
        let ds = Dataset::new(
            "t",
            Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]),
            vec![10.0, 20.0],
        );
        let p = tmpfile("roundtrip.csv");
        save_csv(&p, &ds).unwrap();
        let back = load_csv(&p, "t").unwrap();
        assert_eq!(back.n(), 2);
        assert_eq!(back.dim(), 2);
        assert_eq!(back.y, vec![10.0, 20.0]);
        assert_eq!(back.x.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn skips_header_and_comments() {
        let p = tmpfile("header.csv");
        std::fs::write(&p, "# comment\nf1,f2,target\n1,2,3\n4,5,6\n").unwrap();
        let ds = load_csv(&p, "h").unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.y, vec![3.0, 6.0]);
    }

    #[test]
    fn rejects_ragged() {
        let p = tmpfile("ragged.csv");
        std::fs::write(&p, "1,2,3\n4,5\n").unwrap();
        assert!(load_csv(&p, "r").is_err());
    }

    #[test]
    fn rejects_empty() {
        let p = tmpfile("empty.csv");
        std::fs::write(&p, "# nothing\n").unwrap();
        assert!(load_csv(&p, "e").is_err());
    }

    #[test]
    fn write_table_format() {
        let p = tmpfile("table.csv");
        write_table(&p, &["a", "b"], &[vec![1.0, 2.0], vec![3.5, 4.0]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("a,b\n1,2\n3.5,4\n"));
    }
}
