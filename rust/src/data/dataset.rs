//! Regression dataset container: features + targets, normalization, splits.
//!
//! Experimental protocol follows the paper (§5 "Real data"): normalize to
//! mean 0 / variance 1, random 90/10 train/test split, 5-fold CV on the
//! train portion for hyperparameters, repeated over seeds.

use crate::la::dense::Mat;
use crate::la::stats::standardize;
use crate::util::Rng;

/// A regression dataset (rows of `x` are points).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Mat,
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, x: Mat, y: Vec<f64>) -> Dataset {
        assert_eq!(x.rows, y.len(), "x/y length mismatch");
        Dataset { name: name.into(), x, y }
    }

    pub fn n(&self) -> usize {
        self.x.rows
    }

    pub fn dim(&self) -> usize {
        self.x.cols
    }

    /// Standardize every feature column and the target to mean 0 / std 1
    /// (paper: "The data are normalized to mean zero and variance 1").
    pub fn normalize(&mut self) {
        let (n, d) = (self.x.rows, self.x.cols);
        for j in 0..d {
            let mut col: Vec<f64> = (0..n).map(|i| self.x.at(i, j)).collect();
            standardize(&mut col);
            for i in 0..n {
                self.x.set(i, j, col[i]);
            }
        }
        standardize(&mut self.y);
    }

    /// Subset by row indices.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            name: self.name.clone(),
            x: self.x.gather_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
        }
    }

    /// Random (train, test) split with `train_frac` of rows in train.
    pub fn split(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let n = self.n();
        let mut rng = Rng::new(seed);
        let perm = rng.permutation(n);
        let ntr = ((n as f64) * train_frac).round() as usize;
        let ntr = ntr.clamp(1, n - 1);
        (self.subset(&perm[..ntr]), self.subset(&perm[ntr..]))
    }

    /// k-fold CV index sets: returns (train_idx, val_idx) pairs.
    pub fn kfold(&self, k: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
        let n = self.n();
        let k = k.clamp(2, n);
        let mut rng = Rng::new(seed);
        let perm = rng.permutation(n);
        let mut folds = Vec::with_capacity(k);
        for f in 0..k {
            let lo = f * n / k;
            let hi = (f + 1) * n / k;
            let val: Vec<usize> = perm[lo..hi].to_vec();
            let train: Vec<usize> =
                perm[..lo].iter().chain(perm[hi..].iter()).copied().collect();
            folds.push((train, val));
        }
        folds
    }

    /// Cap the dataset at `max_n` rows (random subsample, seeded).
    pub fn subsample(&self, max_n: usize, seed: u64) -> Dataset {
        if self.n() <= max_n {
            return self.clone();
        }
        let mut rng = Rng::new(seed);
        let idx = rng.sample_indices(self.n(), max_n);
        self.subset(&idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::stats::{mean, std_dev};

    fn toy(n: usize) -> Dataset {
        let x = Mat::from_fn(n, 2, |i, j| (i * 2 + j) as f64);
        let y = (0..n).map(|i| i as f64 * 3.0 + 1.0).collect();
        Dataset::new("toy", x, y)
    }

    #[test]
    fn normalize_standardizes() {
        let mut d = toy(50);
        d.normalize();
        assert!(mean(&d.y).abs() < 1e-12);
        assert!((std_dev(&d.y) - 1.0).abs() < 1e-12);
        let col0 = d.x.col(0);
        assert!(mean(&col0).abs() < 1e-12);
        assert!((std_dev(&col0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn split_sizes_and_disjoint() {
        let d = toy(100);
        let (tr, te) = d.split(0.9, 1);
        assert_eq!(tr.n(), 90);
        assert_eq!(te.n(), 10);
        // disjoint: y values unique in toy, so compare as sets
        let trs: std::collections::HashSet<u64> = tr.y.iter().map(|v| v.to_bits()).collect();
        for v in &te.y {
            assert!(!trs.contains(&v.to_bits()));
        }
    }

    #[test]
    fn split_deterministic() {
        let d = toy(40);
        let (a, _) = d.split(0.8, 7);
        let (b, _) = d.split(0.8, 7);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn kfold_covers_everything() {
        let d = toy(23);
        let folds = d.kfold(5, 3);
        assert_eq!(folds.len(), 5);
        let mut all_val: Vec<usize> = folds.iter().flat_map(|(_, v)| v.clone()).collect();
        all_val.sort_unstable();
        assert_eq!(all_val, (0..23).collect::<Vec<_>>());
        for (tr, va) in &folds {
            assert_eq!(tr.len() + va.len(), 23);
        }
    }

    #[test]
    fn subsample_caps() {
        let d = toy(100);
        let s = d.subsample(30, 1);
        assert_eq!(s.n(), 30);
        let s2 = d.subsample(200, 1);
        assert_eq!(s2.n(), 100);
    }
}
