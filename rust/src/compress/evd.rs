//! Exact-EVD core-diagonal compressor (ablation oracle).
//!
//! Rotating into the full eigenbasis makes the matrix exactly diagonal, so
//! *any* core/wavelet split is exact for the diagonal block itself; the
//! quality difference shows up in how well the core rows compress the
//! *off-diagonal* interactions (paper §3 remark 4). Taking the top-|λ|
//! eigenvectors as the core is the natural oracle: it dominates both MMF
//! and SPCA in per-block Frobenius error at O(m³) cost and a fully dense
//! Q — the ablation benchmark for the cheaper compressors.

use super::{Compression, Compressor, QFactor};
use crate::la::dense::Mat;
use crate::la::evd::SymEig;
use crate::util::Rng;

/// Exact eigendecomposition compressor.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvdCompressor;

impl Compressor for EvdCompressor {
    fn compress(&self, a: &Mat, c_target: usize, _rng: &mut Rng) -> Compression {
        let m = a.rows;
        if c_target >= m || m < 2 {
            return Compression::identity(m);
        }
        let e = SymEig::new(a);
        // Order eigenpairs by |λ| descending; top c become the core.
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&i, &j| {
            e.values[j].abs().partial_cmp(&e.values[i].abs()).unwrap()
        });
        // Q rows are eigenvectors in that order.
        let mut q = Mat::zeros(m, m);
        for (row, &k) in order.iter().enumerate() {
            for i in 0..m {
                q.set(row, i, e.vectors.at(i, k));
            }
        }
        Compression {
            q: QFactor::Dense(q),
            core_local: (0..c_target).collect(),
            wavelet_local: (c_target..m).collect(),
        }
    }

    fn name(&self) -> &'static str {
        "evd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::test_util::{compression_error, is_orthogonal};
    use crate::kernels::{Kernel, RbfKernel};

    fn kernel_block(m: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(m, 3, |_, _| rng.normal());
        let mut k = RbfKernel::new(1.0).gram_sym(&x);
        k.add_diag(0.1);
        k
    }

    #[test]
    fn exact_on_the_block_itself() {
        // In its own eigenbasis a block is diagonal → core-diagonal error 0.
        let a = kernel_block(18, 1);
        let comp = EvdCompressor.compress(&a, 6, &mut Rng::new(0));
        assert!(is_orthogonal(&comp.q.to_dense(18), 1e-8));
        let err = compression_error(&a, &comp);
        assert!(err < 1e-9, "err={err}");
    }

    #[test]
    fn core_carries_top_eigenvalues() {
        let a = kernel_block(12, 2);
        let comp = EvdCompressor.compress(&a, 4, &mut Rng::new(0));
        let q = comp.q.to_dense(12);
        let rot = crate::la::blas::conjugate(&q.transpose(), &a);
        // diagonal must be |λ|-descending over the first entries
        let d = rot.diagonal();
        for i in 0..3 {
            assert!(d[i].abs() >= d[i + 1].abs() - 1e-9);
        }
    }

    #[test]
    fn beats_or_matches_mmf_per_block() {
        let a = kernel_block(24, 3);
        let e_evd = compression_error(&a, &EvdCompressor.compress(&a, 8, &mut Rng::new(0)));
        let e_mmf = compression_error(
            &a,
            &crate::compress::mmf::MmfCompressor::default().compress(&a, 8, &mut Rng::new(0)),
        );
        assert!(e_evd <= e_mmf + 1e-9, "evd={e_evd} mmf={e_mmf}");
    }
}
