//! Augmented Sparse PCA compressor (paper §3 "Augmented Sparse PCA").
//!
//! Finds c sparse, orthonormal loading vectors V maximizing ‖VᵀAV‖_F
//! (truncated power iteration with hard thresholding, Yuan & Zhang-style),
//! orthonormalizes them into Q_sc, and — following the paper — completes
//! with Q_wlet = U·Ô where U is a basis of the orthogonal complement and
//! Ô = argmax_{OᵀO=I} ‖diag(Oᵀ Uᵀ A U O)‖, i.e. the eigenvectors of UᵀAU.
//! This makes the wavelet part of the rotated matrix *exactly* diagonal,
//! which is the Frobenius-optimal completion.

use super::{Compression, Compressor, QFactor};
use crate::la::blas::{dot, gemm, gemm_tn, gemv};
use crate::la::dense::Mat;
use crate::la::evd::SymEig;
use crate::la::qr::{complement_basis, orthonormalize_cols};
use crate::util::Rng;

/// Sparse-PCA-based core-diagonal compressor.
#[derive(Clone, Debug)]
pub struct SpcaCompressor {
    /// Fraction of entries kept per loading vector (sparsity level).
    pub keep_frac: f64,
    /// Power-iteration steps per component.
    pub iters: usize,
}

impl Default for SpcaCompressor {
    fn default() -> Self {
        SpcaCompressor { keep_frac: 0.3, iters: 30 }
    }
}

impl SpcaCompressor {
    /// One sparse principal vector of `a` by truncated power iteration.
    fn sparse_pc(&self, a: &Mat, keep: usize, rng: &mut Rng) -> Vec<f64> {
        let m = a.rows;
        let mut v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        normalize(&mut v);
        for _ in 0..self.iters {
            let mut w = gemv(a, &v);
            hard_threshold(&mut w, keep);
            let n = norm(&w);
            if n < 1e-14 {
                // degenerate direction; restart dense
                v = (0..m).map(|_| rng.normal()).collect();
                normalize(&mut v);
                continue;
            }
            for x in &mut w {
                *x /= n;
            }
            v = w;
        }
        v
    }
}

impl Compressor for SpcaCompressor {
    fn compress(&self, a: &Mat, c_target: usize, rng: &mut Rng) -> Compression {
        let m = a.rows;
        if c_target >= m || m < 2 {
            return Compression::identity(m);
        }
        let c = c_target.max(1);
        let keep = ((m as f64) * self.keep_frac).ceil() as usize;
        let keep = keep.clamp(2.min(m), m);

        // ---- c sparse loading vectors with deflation ----------------------
        let mut defl = a.clone();
        let mut loadings = Mat::zeros(m, c);
        for k in 0..c {
            let v = self.sparse_pc(&defl, keep, rng);
            // Rayleigh quotient for deflation scale.
            let av = gemv(&defl, &v);
            let lam = dot(&v, &av);
            // defl ← defl − λ v vᵀ
            for i in 0..m {
                let vi = v[i];
                if vi == 0.0 {
                    continue;
                }
                let row = defl.row_mut(i);
                for j in 0..m {
                    row[j] -= lam * vi * v[j];
                }
            }
            for i in 0..m {
                loadings.set(i, k, v[i]);
            }
        }

        // ---- orthonormalize into Q_sc; complete with eigenbasis of UᵀAU ---
        let mut q_sc = orthonormalize_cols(&loadings, 1e-10);
        // Guard: if thresholding collapsed directions, pad with random ones.
        let mut guard_rng = Rng::new(0x5bca ^ m as u64);
        while q_sc.cols < c {
            let mut extra = Mat::zeros(m, q_sc.cols + 1);
            extra.set_block(0, 0, &q_sc);
            for i in 0..m {
                extra.set(i, q_sc.cols, guard_rng.normal());
            }
            q_sc = orthonormalize_cols(&extra, 1e-10);
        }
        let u = complement_basis(&q_sc); // m×(m−c)
        let b = gemm_tn(&u, &gemm(a, &u)); // UᵀAU
        let eig = SymEig::new(&b);
        let q_wlet = gemm(&u, &eig.vectors); // m×(m−c)

        // Assemble dense Q with *rows* as output coordinates: first c rows
        // are Q_scᵀ, the rest Q_wletᵀ.
        let mut q = Mat::zeros(m, m);
        for k in 0..c {
            for i in 0..m {
                q.set(k, i, q_sc.at(i, k));
            }
        }
        for k in 0..(m - c) {
            for i in 0..m {
                q.set(c + k, i, q_wlet.at(i, k));
            }
        }

        Compression {
            q: QFactor::Dense(q),
            core_local: (0..c).collect(),
            wavelet_local: (c..m).collect(),
        }
    }

    fn name(&self) -> &'static str {
        "spca"
    }
}

fn norm(v: &[f64]) -> f64 {
    dot(v, v).sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm(v).max(1e-300);
    for x in v.iter_mut() {
        *x /= n;
    }
}

/// Zero all but the `keep` largest-magnitude entries.
fn hard_threshold(v: &mut [f64], keep: usize) {
    if keep >= v.len() {
        return;
    }
    let mut mags: Vec<(f64, usize)> = v.iter().map(|x| x.abs()).zip(0..).collect();
    mags.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let cutoff_set: std::collections::HashSet<usize> =
        mags[..keep].iter().map(|&(_, i)| i).collect();
    for (i, x) in v.iter_mut().enumerate() {
        if !cutoff_set.contains(&i) {
            *x = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::test_util::{compression_error, is_orthogonal};
    use crate::kernels::{Kernel, RbfKernel};

    fn kernel_block(m: usize, seed: u64, ell: f64) -> Mat {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(m, 3, |_, _| rng.normal());
        let mut k = RbfKernel::new(ell).gram_sym(&x);
        k.add_diag(0.1);
        k
    }

    #[test]
    fn q_is_orthogonal() {
        let a = kernel_block(16, 1, 1.5);
        let comp = SpcaCompressor::default().compress(&a, 6, &mut Rng::new(1));
        let q = comp.q.to_dense(16);
        assert!(is_orthogonal(&q, 1e-8));
        assert!(comp.is_valid_for(16));
    }

    #[test]
    fn wavelet_block_exactly_diagonal() {
        // The defining property of the augmented-SPCA completion: the
        // wavelet×wavelet block of QAQᵀ is diagonal.
        let a = kernel_block(14, 2, 1.0);
        let comp = SpcaCompressor::default().compress(&a, 5, &mut Rng::new(2));
        let q = comp.q.to_dense(14);
        let rot = crate::la::blas::conjugate(&q.transpose(), &a);
        for &i in &comp.wavelet_local {
            for &j in &comp.wavelet_local {
                if i != j {
                    assert!(rot.at(i, j).abs() < 1e-8, "({i},{j}) = {}", rot.at(i, j));
                }
            }
        }
    }

    #[test]
    fn approximation_error_reasonable() {
        let a = kernel_block(24, 3, 2.0);
        let comp = SpcaCompressor::default().compress(&a, 12, &mut Rng::new(3));
        let err = compression_error(&a, &comp);
        assert!(err < 0.3, "err={err}");
    }

    #[test]
    fn hard_threshold_keeps_largest() {
        let mut v = vec![0.1, -5.0, 2.0, 0.01, 3.0];
        hard_threshold(&mut v, 2);
        assert_eq!(v, vec![0.0, -5.0, 0.0, 0.0, 3.0]);
    }

    #[test]
    fn loading_vectors_are_sparse() {
        let a = kernel_block(20, 4, 0.7);
        let spca = SpcaCompressor { keep_frac: 0.25, iters: 25 };
        let v = spca.sparse_pc(&a, 5, &mut Rng::new(4));
        let nnz = v.iter().filter(|&&x| x != 0.0).count();
        assert!(nnz <= 5, "nnz={nnz}");
        assert!((norm(&v) - 1.0).abs() < 1e-10);
    }

    #[test]
    fn identity_for_tiny_blocks() {
        let a = Mat::eye(1);
        let comp = SpcaCompressor::default().compress(&a, 1, &mut Rng::new(5));
        assert!(matches!(comp.q, QFactor::Identity));
    }
}
