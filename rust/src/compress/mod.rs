//! Core-diagonal compression (paper Definitions 1–2).
//!
//! A c-core-diagonal compression of a symmetric block A ∈ R^{m×m} is
//! A ≈ Qᵀ H Q with Q orthogonal and H zero except for a c×c "core" block
//! and the remaining diagonal. The **core** rows of Q span the subspace
//! that interacts with the rest of the matrix; the **wavelet** rows carry
//! purely local detail and survive only through their diagonal entries.
//!
//! Three interchangeable compressors (MKA is a meta-algorithm, §3):
//! * [`mmf::MmfCompressor`] — greedy-Jacobi Multiresolution Matrix
//!   Factorization: Q is a product of ⌊(1−γ)m⌋ Givens rotations. Fast and
//!   sparse; the paper's experimental choice.
//! * [`spca::SpcaCompressor`] — augmented sparse PCA: c sparse loading
//!   vectors for the core + exact eigenbasis of the complement.
//! * [`evd::EvdCompressor`] — exact eigendecomposition oracle: optimal
//!   Frobenius split, dense Q, O(m³); upper bound for ablations.

pub mod evd;
pub mod mmf;
pub mod spca;

use crate::la::dense::Mat;
use crate::la::givens::GivensSeq;
use crate::util::Rng;

/// The orthogonal factor produced by a compressor, in block-local
/// coordinates 0..m.
#[derive(Clone, Debug)]
pub enum QFactor {
    /// Product of Givens rotations (MMF): Q = g_L … g_1.
    Givens(GivensSeq),
    /// Dense orthogonal matrix, rows are output coordinates (SPCA/EVD).
    Dense(Mat),
    /// Identity (block too small to compress).
    Identity,
}

impl QFactor {
    /// x ← Q x (block-local vector).
    pub fn apply_vec(&self, x: &mut [f64]) {
        match self {
            QFactor::Givens(seq) => seq.apply_vec(x),
            QFactor::Dense(q) => {
                let y = crate::la::blas::gemv(q, x);
                x.copy_from_slice(&y);
            }
            QFactor::Identity => {}
        }
    }

    /// x ← Qᵀ x.
    pub fn apply_vec_t(&self, x: &mut [f64]) {
        match self {
            QFactor::Givens(seq) => seq.apply_vec_t(x),
            QFactor::Dense(q) => {
                let y = crate::la::blas::gemv_t(q, x);
                x.copy_from_slice(&y);
            }
            QFactor::Identity => {}
        }
    }

    /// Number of stored reals (Proposition 5 storage audits).
    pub fn stored_reals(&self) -> usize {
        match self {
            QFactor::Givens(seq) => seq.stored_reals(),
            QFactor::Dense(q) => q.rows * q.cols,
            QFactor::Identity => 0,
        }
    }

    /// Dense m×m representation (tests only).
    pub fn to_dense(&self, m: usize) -> Mat {
        match self {
            QFactor::Givens(seq) => seq.to_dense(m),
            QFactor::Dense(q) => q.clone(),
            QFactor::Identity => Mat::eye(m),
        }
    }
}

/// Result of core-diagonally compressing one m×m block.
///
/// In the *rotated* coordinates (after applying `q`), positions
/// `core_local` form the dense core and `wavelet_local` are kept only as
/// diagonal entries. Diagonal values are re-read from the globally rotated
/// matrix by the MKA driver, so they are not stored here.
#[derive(Clone, Debug)]
pub struct Compression {
    pub q: QFactor,
    /// Rotated-coordinate positions (block-local) forming the core.
    pub core_local: Vec<usize>,
    /// Rotated-coordinate positions kept as pure diagonal.
    pub wavelet_local: Vec<usize>,
}

impl Compression {
    /// Identity compression: everything is core.
    pub fn identity(m: usize) -> Compression {
        Compression {
            q: QFactor::Identity,
            core_local: (0..m).collect(),
            wavelet_local: Vec::new(),
        }
    }

    /// Sanity: core ∪ wavelet partitions 0..m.
    pub fn is_valid_for(&self, m: usize) -> bool {
        let mut seen = vec![false; m];
        for &i in self.core_local.iter().chain(&self.wavelet_local) {
            if i >= m || seen[i] {
                return false;
            }
            seen[i] = true;
        }
        seen.iter().all(|&b| b)
    }
}

/// A core-diagonal compressor: given a symmetric block and a target core
/// size, produce the rotation and the core/wavelet split.
pub trait Compressor: Send + Sync {
    fn compress(&self, a: &Mat, c_target: usize, rng: &mut Rng) -> Compression;
    fn name(&self) -> &'static str;
}

/// Which compressor to use (config / CLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressorKind {
    Mmf,
    Spca,
    Evd,
}

impl CompressorKind {
    pub fn parse(s: &str) -> CompressorKind {
        match s {
            "spca" => CompressorKind::Spca,
            "evd" => CompressorKind::Evd,
            _ => CompressorKind::Mmf,
        }
    }

    pub fn build(self) -> Box<dyn Compressor> {
        match self {
            CompressorKind::Mmf => Box::new(mmf::MmfCompressor::default()),
            CompressorKind::Spca => Box::new(spca::SpcaCompressor::default()),
            CompressorKind::Evd => Box::new(evd::EvdCompressor),
        }
    }
}

/// Frobenius error of the core-diagonal approximation implied by a
/// compression: A ≈ Qᵀ H Q with H = rotated A restricted to core×core +
/// diagonal at wavelet positions. O(m³) — diagnostics and ablations only.
pub fn compression_error(a: &Mat, comp: &Compression) -> f64 {
    use crate::la::blas::conjugate;
    let m = a.rows;
    let q = comp.q.to_dense(m);
    // rotated = Q A Qᵀ
    let rotated = conjugate(&q.transpose(), a);
    // build H: core block dense + wavelet diagonal
    let mut h = Mat::zeros(m, m);
    for &i in &comp.core_local {
        for &j in &comp.core_local {
            h.set(i, j, rotated.at(i, j));
        }
    }
    for &i in &comp.wavelet_local {
        h.set(i, i, rotated.at(i, i));
    }
    // reconstruct: Qᵀ H Q
    let rec = conjugate(&q, &h);
    rec.sub(a).frob_norm() / a.frob_norm().max(1e-300)
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::*;

    pub use super::compression_error;

    pub fn is_orthogonal(q: &Mat, tol: f64) -> bool {
        let qtq = crate::la::blas::gemm_tn(q, q);
        qtq.sub(&Mat::eye(q.cols)).max_abs() < tol
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_compression_valid() {
        let c = Compression::identity(5);
        assert!(c.is_valid_for(5));
        assert_eq!(c.core_local.len(), 5);
        assert_eq!(c.q.stored_reals(), 0);
    }

    #[test]
    fn validity_detects_overlap() {
        let c = Compression {
            q: QFactor::Identity,
            core_local: vec![0, 1],
            wavelet_local: vec![1, 2],
        };
        assert!(!c.is_valid_for(3));
    }

    #[test]
    fn kind_parse() {
        assert_eq!(CompressorKind::parse("mmf"), CompressorKind::Mmf);
        assert_eq!(CompressorKind::parse("spca"), CompressorKind::Spca);
        assert_eq!(CompressorKind::parse("evd"), CompressorKind::Evd);
        assert_eq!(CompressorKind::parse("???"), CompressorKind::Mmf);
    }

    #[test]
    fn qfactor_identity_apply() {
        let q = QFactor::Identity;
        let mut x = vec![1.0, 2.0];
        q.apply_vec(&mut x);
        assert_eq!(x, vec![1.0, 2.0]);
        q.apply_vec_t(&mut x);
        assert_eq!(x, vec![1.0, 2.0]);
    }
}
