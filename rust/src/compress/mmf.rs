//! Greedy-Jacobi Multiresolution Matrix Factorization compressor
//! (Kondor, Teneva & Garg 2014; paper §3 "MMF" and §4 feature list).
//!
//! Given a symmetric block A (m×m) and target core size c, performs
//! m − c greedy Givens steps. Each step picks a pair (i, j) of active
//! coordinates, rotates in their plane, and **retires** one rotated
//! coordinate as a wavelet: from then on only its diagonal entry survives,
//! so the approximation error contributed by that coordinate is exactly
//! its remaining off-diagonal energy.
//!
//! Pivot rules (selectable; the min-residual rule is the default and the
//! max-correlation rule is kept for the ablation bench):
//!
//! * **MinResidual** — for each candidate pair, the rotation angle that
//!   minimizes the retired row's off-diagonal energy has a closed form:
//!   writing M for the 2×2 Gram matrix of rows i, j restricted to the
//!   *outside* coordinates (obtainable in O(1) from G = AᵀA), the optimal
//!   retired direction is the λ_min-eigenvector of M and the residual is
//!   λ_min + (rotated A_ij)². We also evaluate the classic Jacobi angle
//!   (which zeroes A_ij instead) and keep whichever is better; the pair
//!   with the globally smallest residual is rotated.
//! * **MaxCorrelation** — the original MMF heuristic: rotate the pair with
//!   maximal normalized Gram correlation |G_ij|/√(G_ii G_jj) by the Jacobi
//!   angle and retire the rotated coordinate with less off-diagonal
//!   energy.
//!
//! Computing G = AᵀA is the m³ BLAS hot spot the paper points to
//! (Prop. 4) — the MKA driver can hand blocks to the AOT'd XLA `ata`
//! artifact for exactly this product.

use super::{Compression, Compressor, QFactor};
use crate::la::blas::syrk_ata;
use crate::la::dense::Mat;
use crate::la::givens::{Givens, GivensSeq};
use crate::util::Rng;

/// Pivot-selection rule for the greedy loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PivotRule {
    /// Minimize the truncated off-diagonal energy (recommended).
    #[default]
    MinResidual,
    /// Classic MMF max-normalized-correlation heuristic.
    MaxCorrelation,
}

/// Greedy-Jacobi MMF core-diagonal compressor.
#[derive(Clone, Debug)]
pub struct MmfCompressor {
    pub rule: PivotRule,
    /// Extra classical-Jacobi rotations performed on the active set before
    /// each retirement (0 = the strict one-rotation-per-wavelet scheme of
    /// the paper's Prop. 4/5 accounting; small values trade a constant
    /// factor of storage/FLOPs for substantially lower truncation error —
    /// MMF's rotation count stays O(m) either way).
    pub extra_rotations: usize,
}

impl Default for MmfCompressor {
    fn default() -> Self {
        MmfCompressor { rule: PivotRule::MinResidual, extra_rotations: 2 }
    }
}

/// Outcome of scoring one candidate pair.
#[derive(Clone, Copy)]
struct PairPlan {
    score: f64,
    /// Rotation (c, s) in the (i, j) plane.
    c: f64,
    s: f64,
}

impl MmfCompressor {
    pub fn with_rule(rule: PivotRule) -> MmfCompressor {
        MmfCompressor { rule, ..MmfCompressor::default() }
    }

    /// Compress with an externally computed Gram matrix G = AᵀA (e.g. from
    /// the XLA artifact). `a` and `g` are cloned as working copies.
    ///
    /// Hot path: a rotation in the (i, j) plane only changes matrix entries
    /// in rows/columns i and j, so per-row caches of the best pivot partner
    /// and the largest off-diagonal entry stay valid for all other pairs —
    /// each greedy step costs O(m) amortized instead of O(active²) (the
    /// §Perf optimization recorded in EXPERIMENTS.md).
    pub fn compress_with_gram(&self, a: &Mat, g: &Mat, c_target: usize) -> Compression {
        let m = a.rows;
        assert!(a.is_square() && g.is_square() && g.rows == m);
        if c_target >= m || m < 2 {
            return Compression::identity(m);
        }
        let mut a = a.clone();
        let mut g = g.clone();
        let mut active: Vec<bool> = vec![true; m];
        let mut n_active = m;
        let mut seq = GivensSeq::new();
        let mut wavelet = Vec::with_capacity(m - c_target);

        // ---- per-row caches ---------------------------------------------
        // rowmax[p]: (partner, |A_pq|) with the largest off-diagonal entry.
        // best[p]:   (partner, plan) with the lowest pivot score.
        let rescan_max = |a: &Mat, active: &[bool], p: usize| -> Option<(usize, f64)> {
            let row = a.row(p);
            let mut out: Option<(usize, f64)> = None;
            for (q, v) in row.iter().enumerate() {
                if q != p && active[q] {
                    let av = v.abs();
                    if out.map_or(true, |(_, b)| av > b) {
                        out = Some((q, av));
                    }
                }
            }
            out
        };
        let rule = self.rule;
        let rescan_best = |a: &Mat, g: &Mat, active: &[bool], p: usize| -> Option<(usize, PairPlan)> {
            let mut out: Option<(usize, PairPlan)> = None;
            for q in 0..a.rows {
                if q == p || !active[q] {
                    continue;
                }
                let (i, j) = (p.min(q), p.max(q));
                let plan = match rule {
                    PivotRule::MinResidual => plan_min_residual(a, g, i, j),
                    PivotRule::MaxCorrelation => plan_max_correlation(a, g, i, j),
                };
                if out.map_or(true, |(_, b)| plan.score < b.score) {
                    out = Some((q, plan));
                }
            }
            out
        };

        let mut rowmax: Vec<Option<(usize, f64)>> =
            (0..m).map(|p| rescan_max(&a, &active, p)).collect();
        let mut best: Vec<Option<(usize, PairPlan)>> =
            (0..m).map(|p| rescan_best(&a, &g, &active, p)).collect();

        // Refresh both caches after a rotation in the (i, j) plane: rows
        // i/j rescan; other rows incrementally absorb the changed columns,
        // falling back to a rescan when their cached entry went stale.
        macro_rules! refresh_after_rotation {
            ($i:expr, $j:expr) => {{
                let (ri, rj) = ($i, $j);
                for p in 0..m {
                    if !active[p] {
                        continue;
                    }
                    if p == ri || p == rj {
                        rowmax[p] = rescan_max(&a, &active, p);
                        best[p] = rescan_best(&a, &g, &active, p);
                        continue;
                    }
                    // rowmax: columns ri, rj changed in row p.
                    match rowmax[p] {
                        Some((q, _)) if q == ri || q == rj => {
                            rowmax[p] = rescan_max(&a, &active, p);
                        }
                        Some((q, v)) => {
                            let cand_i = if active[ri] { a.at(p, ri).abs() } else { 0.0 };
                            let cand_j = if active[rj] { a.at(p, rj).abs() } else { 0.0 };
                            if cand_i > v || cand_j > v {
                                let (nq, nv) = if cand_i >= cand_j { (ri, cand_i) } else { (rj, cand_j) };
                                rowmax[p] = Some((nq, nv));
                            } else {
                                rowmax[p] = Some((q, v));
                            }
                        }
                        None => rowmax[p] = rescan_max(&a, &active, p),
                    }
                    // best: pair scores involving ri/rj changed.
                    match best[p] {
                        Some((q, _)) if q == ri || q == rj => {
                            best[p] = rescan_best(&a, &g, &active, p);
                        }
                        Some((q, plan)) => {
                            let mut cur = Some((q, plan));
                            for &t in &[ri, rj] {
                                if t != p && active[t] {
                                    let (lo, hi) = (p.min(t), p.max(t));
                                    let np = match rule {
                                        PivotRule::MinResidual => plan_min_residual(&a, &g, lo, hi),
                                        PivotRule::MaxCorrelation => {
                                            plan_max_correlation(&a, &g, lo, hi)
                                        }
                                    };
                                    if cur.map_or(true, |(_, b)| np.score < b.score) {
                                        cur = Some((t, np));
                                    }
                                }
                            }
                            best[p] = cur;
                        }
                        None => best[p] = rescan_best(&a, &g, &active, p),
                    }
                }
            }};
        }

        // Invalidate cache entries pointing at a retired coordinate.
        macro_rules! refresh_after_retire {
            ($r:expr) => {{
                let r = $r;
                for p in 0..m {
                    if !active[p] {
                        continue;
                    }
                    if matches!(rowmax[p], Some((q, _)) if q == r) {
                        rowmax[p] = rescan_max(&a, &active, p);
                    }
                    if matches!(best[p], Some((q, _)) if q == r) {
                        best[p] = rescan_best(&a, &g, &active, p);
                    }
                }
            }};
        }

        while n_active > c_target.max(1) {
            // ---- optional pre-sweep: classical Jacobi on the largest
            // off-diagonal entries among active pairs ----------------------
            for _ in 0..self.extra_rotations {
                let mut pick: Option<(usize, usize, f64)> = None;
                for p in 0..m {
                    if !active[p] {
                        continue;
                    }
                    if let Some((q, v)) = rowmax[p] {
                        if pick.map_or(true, |(_, _, b)| v > b) {
                            pick = Some((p, q, v));
                        }
                    }
                }
                let Some((bi, bj, bv)) = pick else { break };
                if bv < 1e-14 {
                    break;
                }
                let (bi, bj) = (bi.min(bj), bi.max(bj));
                let rot = Givens::jacobi(bi, bj, a.at(bi, bi), a.at(bi, bj), a.at(bj, bj));
                rot.conjugate_sym(&mut a);
                rot.conjugate_sym(&mut g);
                seq.push(rot);
                refresh_after_rotation!(bi, bj);
            }

            // ---- greedy pivot from the cache ------------------------------
            let mut pick: Option<(usize, usize, PairPlan)> = None;
            for p in 0..m {
                if !active[p] {
                    continue;
                }
                if let Some((q, plan)) = best[p] {
                    if pick.map_or(true, |(_, _, b)| plan.score < b.score) {
                        pick = Some((p.min(q), p.max(q), plan));
                    }
                }
            }
            let Some((bi, bj, plan)) = pick else { break };

            let rot = Givens { i: bi, j: bj, c: plan.c, s: plan.s };
            rot.conjugate_sym(&mut a);
            rot.conjugate_sym(&mut g);
            seq.push(rot);

            // The rotation was chosen so that the *new j* coordinate is the
            // best wavelet for MinResidual; for MaxCorrelation compare the
            // two rotated rows' off-diagonal energies.
            let retire = match self.rule {
                PivotRule::MinResidual => bj,
                PivotRule::MaxCorrelation => {
                    if off_energy(&a, bi) <= off_energy(&a, bj) {
                        bi
                    } else {
                        bj
                    }
                }
            };
            active[retire] = false;
            n_active -= 1;
            wavelet.push(retire);
            refresh_after_rotation!(bi, bj);
            refresh_after_retire!(retire);
        }

        let core: Vec<usize> = (0..m).filter(|&i| active[i]).collect();
        Compression { q: QFactor::Givens(seq), core_local: core, wavelet_local: wavelet }
    }
}

/// Off-diagonal energy of row k (all coordinates — retired rows' entries
/// are truncated too, so they count).
#[inline]
fn off_energy(a: &Mat, k: usize) -> f64 {
    let row = a.row(k);
    let mut s = 0.0;
    for (l, v) in row.iter().enumerate() {
        if l != k {
            s += v * v;
        }
    }
    s
}

/// Min-residual scoring: closed-form best rotation for pair (i, j).
#[inline]
fn plan_min_residual(a: &Mat, g: &Mat, i: usize, j: usize) -> PairPlan {
    let aii = a.at(i, i);
    let ajj = a.at(j, j);
    let aij = a.at(i, j);
    // Outside-coordinate Gram of rows i, j:
    //   M_ab = Σ_{k∉{i,j}} A_ak A_bk = G_ab − A_ai A_bi − A_aj A_bj.
    let m_ii = (g.at(i, i) - aii * aii - aij * aij).max(0.0);
    let m_jj = (g.at(j, j) - aij * aij - ajj * ajj).max(0.0);
    let m_ij = g.at(i, j) - aii * aij - aij * ajj;

    // Candidate 1: retire along the λ_min eigenvector of M.
    let tr = m_ii + m_jj;
    let disc = ((m_ii - m_jj) * (m_ii - m_jj) + 4.0 * m_ij * m_ij).sqrt();
    let lam_min = 0.5 * (tr - disc).max(0.0);
    // Unit eigenvector (v0, v1) for λ_min; retired direction = (−s, c).
    let (v0, v1) = eigvec2(m_ii, m_ij, m_jj, 0.5 * (tr - disc));
    let (c1, s1) = (v1, -v0);
    // Rotated in-block entry A'_ij for this angle.
    let aij_rot = s1 * c1 * (ajj - aii) + (c1 * c1 - s1 * s1) * aij;
    let score1 = lam_min + aij_rot * aij_rot;

    // Candidate 2: classic Jacobi angle (zeroes A'_ij), retired row = new j.
    let gj = Givens::jacobi(0, 1, aii, aij, ajj);
    let (c2, s2) = (gj.c, gj.s);
    // Energy of new row j outside {i, j}: [−s, c] M [−s, c]ᵀ.
    let e_j = s2 * s2 * m_ii - 2.0 * s2 * c2 * m_ij + c2 * c2 * m_jj;
    // And of new row i: [c, s] M [c, s]ᵀ (we could retire i by swapping —
    // equivalent to angle choice, so just take the better of the two).
    let e_i = c2 * c2 * m_ii + 2.0 * s2 * c2 * m_ij + s2 * s2 * m_jj;
    let score2 = e_j.min(e_i);

    if score1 <= score2 {
        PairPlan { score: score1, c: c1, s: s1 }
    } else if e_j <= e_i {
        PairPlan { score: score2, c: c2, s: s2 }
    } else {
        // Retire "new i" instead: compose with a quarter turn so the
        // retired coordinate is still the j slot:
        // (c, s) ← (−s, c) maps new-j to old new-i direction.
        PairPlan { score: score2, c: -s2, s: c2 }
    }
}

/// Classic MMF scoring: maximal normalized correlation, Jacobi angle.
/// (Score is negated correlation so that "smaller is better" uniformly.)
#[inline]
fn plan_max_correlation(a: &Mat, g: &Mat, i: usize, j: usize) -> PairPlan {
    let gii = g.at(i, i).max(1e-300);
    let gjj = g.at(j, j).max(1e-300);
    let corr = g.at(i, j).abs() / (gii * gjj).sqrt();
    let gj = Givens::jacobi(0, 1, a.at(i, i), a.at(i, j), a.at(j, j));
    PairPlan { score: -corr, c: gj.c, s: gj.s }
}

/// Unit eigenvector of [[a, b], [b, d]] for eigenvalue `lam`.
#[inline]
fn eigvec2(a: f64, b: f64, d: f64, lam: f64) -> (f64, f64) {
    // (a − λ) v0 + b v1 = 0
    let (mut v0, mut v1) = if b.abs() > 1e-300 {
        (b, lam - a)
    } else if a <= d {
        (1.0, 0.0)
    } else {
        (0.0, 1.0)
    };
    let n = (v0 * v0 + v1 * v1).sqrt();
    if n < 1e-300 {
        return (1.0, 0.0);
    }
    v0 /= n;
    v1 /= n;
    let _ = d;
    (v0, v1)
}

impl Compressor for MmfCompressor {
    fn compress(&self, a: &Mat, c_target: usize, _rng: &mut Rng) -> Compression {
        if c_target >= a.rows || a.rows < 2 {
            return Compression::identity(a.rows);
        }
        let g = syrk_ata(a);
        self.compress_with_gram(a, &g, c_target)
    }

    fn name(&self) -> &'static str {
        "mmf"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::test_util::compression_error;
    use crate::kernels::{Kernel, RbfKernel};
    use crate::la::blas::gemm_nt;

    fn kernel_block(m: usize, seed: u64, ell: f64) -> Mat {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(m, 3, |_, _| rng.normal());
        let mut k = RbfKernel::new(ell).gram_sym(&x);
        k.add_diag(0.1);
        k
    }

    #[test]
    fn rotation_count_matches_paper() {
        // With no pre-sweeps, Q is a product of exactly m − c Givens
        // rotations (the paper's Prop. 4/5 accounting).
        let a = kernel_block(24, 1, 1.0);
        let mmf = MmfCompressor { extra_rotations: 0, ..MmfCompressor::default() };
        let comp = mmf.compress(&a, 12, &mut Rng::new(0));
        match &comp.q {
            QFactor::Givens(seq) => assert_eq!(seq.len(), 12),
            _ => panic!("expected Givens"),
        }
        assert_eq!(comp.core_local.len(), 12);
        assert_eq!(comp.wavelet_local.len(), 12);
        assert!(comp.is_valid_for(24));
    }

    #[test]
    fn identity_when_no_compression_requested() {
        let a = kernel_block(8, 2, 1.0);
        let comp = MmfCompressor::default().compress(&a, 8, &mut Rng::new(0));
        assert!(matches!(comp.q, QFactor::Identity));
        assert_eq!(comp.core_local.len(), 8);
    }

    #[test]
    fn approximation_error_small_on_kernel_blocks() {
        // A smooth kernel block compresses well at γ = 1/2.
        let a = kernel_block(32, 3, 2.0);
        let comp = MmfCompressor::default().compress(&a, 16, &mut Rng::new(0));
        let err = compression_error(&a, &comp);
        assert!(err < 0.12, "relative error {err}");
    }

    #[test]
    fn min_residual_beats_max_correlation() {
        let a = kernel_block(40, 4, 0.8);
        let e_min = compression_error(
            &a,
            &MmfCompressor::with_rule(PivotRule::MinResidual).compress(&a, 20, &mut Rng::new(0)),
        );
        let e_cor = compression_error(
            &a,
            &MmfCompressor::with_rule(PivotRule::MaxCorrelation).compress(&a, 20, &mut Rng::new(0)),
        );
        assert!(e_min <= e_cor + 1e-9, "min-residual {e_min} vs correlation {e_cor}");
    }

    #[test]
    fn error_decreases_with_core_size() {
        let a = kernel_block(40, 4, 0.8);
        let mmf = MmfCompressor::default();
        let e_small = compression_error(&a, &mmf.compress(&a, 8, &mut Rng::new(0)));
        let e_large = compression_error(&a, &mmf.compress(&a, 32, &mut Rng::new(0)));
        assert!(
            e_large <= e_small + 1e-9,
            "larger core should not be worse: {e_large} vs {e_small}"
        );
    }

    #[test]
    fn diagonal_matrix_is_free() {
        // A diagonal matrix is already core-diagonal: error ~ 0 at any c.
        let a = Mat::diag(&[5.0, 4.0, 3.0, 2.0, 1.0, 0.5]);
        let comp = MmfCompressor::default().compress(&a, 2, &mut Rng::new(0));
        let err = compression_error(&a, &comp);
        assert!(err < 1e-10, "err={err}");
    }

    #[test]
    fn compress_with_external_gram_matches_internal() {
        let a = kernel_block(20, 5, 1.0);
        let g = syrk_ata(&a);
        let mmf = MmfCompressor::default();
        let c1 = mmf.compress(&a, 10, &mut Rng::new(0));
        let c2 = mmf.compress_with_gram(&a, &g, 10);
        assert_eq!(c1.core_local, c2.core_local);
        assert_eq!(c1.wavelet_local, c2.wavelet_local);
    }

    #[test]
    fn spsd_preservation_of_core() {
        // Core block of the rotated matrix must stay psd (Prop. 1).
        let mut rng = Rng::new(6);
        let b = Mat::from_fn(18, 18, |_, _| rng.normal());
        let a = gemm_nt(&b, &b); // psd
        let comp = MmfCompressor::default().compress(&a, 9, &mut Rng::new(0));
        let q = comp.q.to_dense(18);
        let rotated = crate::la::blas::conjugate(&q.transpose(), &a);
        let core = rotated.gather(&comp.core_local, &comp.core_local);
        let e = crate::la::evd::SymEig::new(&core);
        assert!(e.values[0] > -1e-8, "core min eig {}", e.values[0]);
        // wavelet diagonal entries are nonnegative
        for &w in &comp.wavelet_local {
            assert!(rotated.at(w, w) > -1e-9);
        }
    }

    #[test]
    fn quarter_turn_composition_is_orthogonal() {
        // The retire-new-i branch composes a quarter turn; the resulting
        // sequence must still be orthogonal.
        let a = kernel_block(16, 7, 0.5);
        let comp = MmfCompressor::default().compress(&a, 4, &mut Rng::new(0));
        let q = comp.q.to_dense(16);
        let qtq = crate::la::blas::gemm_tn(&q, &q);
        assert!(qtq.sub(&Mat::eye(16)).max_abs() < 1e-10);
    }
}
