//! Integration tests across the GP stack: all six methods of the paper's
//! evaluation on catalog datasets, checked for the paper's qualitative
//! ordering and for metric sanity.

use mka_gp::data::synth::{gp_dataset, snelson1d, table1_k, table1_specs, SynthSpec};
use mka_gp::experiments::methods::{run_method, Method};
use mka_gp::experiments::{snelson, sweep};
use mka_gp::gp::cv::{default_grid, grid_search, HyperParams};
use mka_gp::gp::full::FullGp;
use mka_gp::gp::GpModel;
use mka_gp::kernels::RbfKernel;

mod common;
use common::{small_cfg, synth, SIGMA2};

#[test]
fn all_six_methods_on_all_catalog_datasets() {
    // Subsampled catalog: every method must produce finite, non-degenerate
    // predictions on every dataset geometry (n, d) of Table 1.
    let hp = HyperParams { lengthscale: 0.8, sigma2: 0.1 };
    for spec in table1_specs() {
        let data = gp_dataset(&spec, 11).subsample(220, 1);
        let (tr, te) = data.split(0.9, 2);
        let k = table1_k(&spec.name).min(tr.n() / 4);
        for m in Method::ALL {
            let r = run_method(m, &tr, &te, hp, k, 3)
                .unwrap_or_else(|e| panic!("{m:?} on {}: {e}", spec.name));
            assert!(
                r.smse.is_finite() && r.smse < 3.0,
                "{m:?} on {}: smse {}",
                spec.name,
                r.smse
            );
        }
    }
}

#[test]
fn paper_ordering_on_broad_spectrum_data() {
    // The Table-1 shape: Full best, MKA closest to Full among
    // approximations, averaged over a few splits.
    let spec = SynthSpec { ell_local: 0.4, local_weight: 0.55, ..SynthSpec::named("ord", 450, 3) };
    let data = gp_dataset(&spec, 21);
    let hp = HyperParams { lengthscale: 0.45, sigma2: 0.1 };
    let k = 16;
    let mut sums = std::collections::BTreeMap::new();
    for rep in 0..3u64 {
        let (tr, te) = data.split(0.9, rep);
        for m in Method::ALL {
            if let Ok(r) = run_method(m, &tr, &te, hp, k, rep) {
                *sums.entry(m.label()).or_insert(0.0) += r.smse / 3.0;
            }
        }
    }
    let get = |m: &str| *sums.get(m).unwrap_or(&f64::INFINITY);
    let full = get("Full");
    let mka = get("MKA");
    let low_rank_best = get("SOR").min(get("FITC")).min(get("PITC"));
    assert!(full <= mka + 0.15, "Full {full} should lead MKA {mka}");
    assert!(
        mka < low_rank_best + 0.05,
        "MKA {mka} should beat/track best low-rank {low_rank_best} (sums: {sums:?})"
    );
}

#[test]
fn cv_then_fit_pipeline() {
    // The §5 protocol end to end: CV grid → best hp → final fit → sane SMSE.
    let data = synth("cvp", 240, 2, 31);
    let (tr, te) = data.split(0.9, 1);
    let grid = default_grid(2);
    let out = grid_search(&tr, 3, &grid, 5, |t, vx, hp| {
        let gp = FullGp::fit(t, &RbfKernel::new(hp.lengthscale), hp.sigma2).ok()?;
        Some(gp.predict(vx).mean)
    })
    .expect("CV grid fully failed");
    assert!(out.best_score < 1.0, "CV best {}", out.best_score);
    let model = FullGp::fit(&tr, &RbfKernel::new(out.best.lengthscale), out.best.sigma2).unwrap();
    let pred = model.predict(&te.x);
    let e = mka_gp::gp::metrics::smse(&te.y, &pred.mean);
    assert!(e < 1.0, "test smse {e}");
}

#[test]
fn snelson_figure_shape() {
    // Figure 1: MKA's deviation from Full must be the smallest.
    let hp = HyperParams { lengthscale: 0.5, sigma2: 0.01 };
    let (_d, curves) = snelson::run(180, 10, 150, hp, &Method::ALL, 3);
    let dev = snelson::deviation_from_full(&curves);
    let mka = dev.iter().find(|(m, _)| *m == Method::Mka).unwrap().1;
    for (m, d) in &dev {
        if *m != Method::Mka {
            assert!(mka <= d + 0.03, "MKA dev {mka} vs {m:?} {d}");
        }
    }
}

#[test]
fn snelson_data_reproducible() {
    let a = snelson1d(100, 9);
    let b = snelson1d(100, 9);
    assert_eq!(a.y, b.y);
}

#[test]
fn figure2_flatness_shape() {
    // MKA must degrade less than SoR when k shrinks (averaged over seeds).
    let spec = SynthSpec { ell_local: 0.4, local_weight: 0.5, ..SynthSpec::named("flat", 400, 3) };
    let data = gp_dataset(&spec, 41);
    let hp = HyperParams { lengthscale: 0.45, sigma2: 0.1 };
    let mut sor_gap = 0.0;
    let mut mka_gap = 0.0;
    for seed in 0..2u64 {
        let pts = sweep::sweep(&data, &[8, 64], hp, &[Method::Sor, Method::Mka], seed);
        let at = |m: Method, k: usize| {
            pts.iter().find(|p| p.method == m && p.k == k).unwrap().smse
        };
        sor_gap += at(Method::Sor, 8) - at(Method::Sor, 64);
        mka_gap += at(Method::Mka, 8) - at(Method::Mka, 64);
    }
    assert!(
        mka_gap <= sor_gap + 0.1,
        "MKA gap {mka_gap} should be flatter than SoR gap {sor_gap}"
    );
}

#[test]
fn variance_calibration_on_heldout() {
    // Predictive z-scores (y−μ)/σ must have roughly unit scale for the
    // calibrated methods (Full, MKA).
    let data = synth("cal", 300, 2, 51);
    let (tr, te) = data.split(0.9, 1);
    let kern = RbfKernel::new(0.5);
    let cfg = mka_gp::mka::MkaConfig { d_core: 32, block_size: 80, ..small_cfg(0) };
    for (name, pred) in [
        ("full", FullGp::fit(&tr, &kern, SIGMA2).unwrap().predict(&te.x)),
        (
            "mka",
            mka_gp::gp::mka_gp::MkaGp::fit(&tr, &kern, SIGMA2, &cfg).unwrap().predict(&te.x),
        ),
    ] {
        let z2: f64 = te
            .y
            .iter()
            .zip(&pred.mean)
            .zip(&pred.var)
            .map(|((y, m), v)| (y - m) * (y - m) / v.max(1e-12))
            .sum::<f64>()
            / te.n() as f64;
        assert!((0.1..10.0).contains(&z2), "{name}: mean squared z-score {z2}");
    }
}
