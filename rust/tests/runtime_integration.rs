//! Integration tests for the PJRT runtime: load the real AOT artifacts,
//! execute them, and verify numerics against the native kernels.
//!
//! These tests need `make artifacts` to have run; when the artifacts are
//! missing they print a skip notice and pass (so `cargo test` works in a
//! fresh checkout), but CI runs them for real via `make test`.

use std::path::Path;
use std::sync::Arc;

use mka_gp::kernels::gram::{rbf_tile_native, GramBuilder, TileEngine};
use mka_gp::kernels::{Kernel, RbfKernel};
use mka_gp::la::{syrk_ata, Chol, Mat};
use mka_gp::runtime::engine::XlaEngine;
use mka_gp::util::Rng;

fn engine() -> Option<XlaEngine> {
    if cfg!(not(feature = "xla")) {
        eprintln!("SKIP: built without the `xla` feature (PJRT backend stubbed)");
        return None;
    }
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(XlaEngine::start(dir).expect("engine start"))
}

#[test]
fn gram_tile_matches_native_exactly() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let mut rng = Rng::new(1);
    for (r, c, d) in [(128, 128, 32), (64, 128, 8), (5, 7, 3), (1, 1, 1)] {
        let x = Mat::from_fn(r, d, |_, _| rng.normal());
        let y = Mat::from_fn(c, d, |_, _| rng.normal());
        for ell in [0.3, 1.0, 4.0] {
            let xla = h.rbf_tile(&x, &y, ell, 1.2).unwrap();
            let native = rbf_tile_native(&x, &y, ell, 1.2);
            assert!(
                xla.sub(&native).max_abs() < 1e-12,
                "tile {r}x{c}x{d} ell={ell}"
            );
        }
    }
}

#[test]
fn ata_matches_native() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let mut rng = Rng::new(2);
    for m in [256, 200, 64, 3] {
        let a = Mat::from_fn(m, m, |_, _| rng.normal());
        let xla = h.ata(&a).unwrap();
        let native = syrk_ata(&a);
        assert!(xla.sub(&native).max_abs() < 1e-10, "ata m={m}");
    }
}

#[test]
fn chol_solve_matches_native() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let mut rng = Rng::new(3);
    for n in [512, 300, 50] {
        let b = Mat::from_fn(n, n + 4, |_, _| rng.normal());
        let mut k = mka_gp::la::gemm_nt(&b, &b);
        k.scale(1.0 / (n as f64 + 4.0));
        let y = rng.normal_vec(n);
        let xla = h.chol_solve(&k, &y, 0.2).unwrap();
        let mut kp = k.clone();
        kp.add_diag(0.2);
        let native = Chol::new(&kp).unwrap().solve(&y);
        let err = xla
            .iter()
            .zip(&native)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 1e-6, "chol n={n}: err {err}");
    }
}

#[test]
fn gram_builder_through_engine_matches_direct() {
    let Some(engine) = engine() else { return };
    let handle = engine.handle();
    let mut rng = Rng::new(4);
    // deliberately ragged size and smaller dim than the artifact's 32
    let x = Mat::from_fn(301, 5, |_, _| rng.normal());
    let builder = GramBuilder::rbf(0.9, 1.0, Some(Arc::new(handle) as Arc<dyn TileEngine>));
    assert!(builder.has_engine());
    let k_eng = builder.build_sym(&x);
    let k_direct = RbfKernel::new(0.9).gram_sym(&x);
    assert!(k_eng.sub(&k_direct).max_abs() < 1e-12);
}

#[test]
fn oversize_requests_rejected() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let big = Mat::zeros(h.gram_tile_size() + 1, 4);
    assert!(h.rbf_tile(&big, &big, 1.0, 1.0).is_err());
    let big_a = Mat::zeros(600, 600);
    assert!(h.ata(&big_a).is_err());
}

#[test]
fn engine_is_thread_safe_handle() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(t as u64);
                let x = Mat::from_fn(32, 4, |_, _| rng.normal());
                let out = h.rbf_tile(&x, &x, 1.0, 1.0).unwrap();
                let native = rbf_tile_native(&x, &x, 1.0, 1.0);
                assert!(out.sub(&native).max_abs() < 1e-12);
            })
        })
        .collect();
    for t in handles {
        t.join().unwrap();
    }
}

#[test]
fn mka_gp_with_engine_backed_gram() {
    let Some(engine) = engine() else { return };
    use mka_gp::data::synth::{gp_dataset, SynthSpec};
    use mka_gp::gp::mka_gp::MkaGp;
    use mka_gp::gp::GpModel;
    let data = gp_dataset(&SynthSpec::named("eng", 200, 3), 5);
    let (tr, te) = data.split(0.9, 1);
    let kern = RbfKernel::new(0.7);
    let cfg = mka_gp::mka::MkaConfig { d_core: 24, block_size: 64, ..Default::default() };
    let plain = MkaGp::fit(&tr, &kern, 0.1, &cfg).unwrap();
    let with_engine = MkaGp::fit(&tr, &kern, 0.1, &cfg)
        .unwrap()
        .with_gram_builder(GramBuilder::rbf(
            0.7,
            1.0,
            Some(Arc::new(engine.handle()) as Arc<dyn TileEngine>),
        ));
    let p1 = plain.predict(&te.x);
    let p2 = with_engine.predict(&te.x);
    for i in 0..te.n() {
        assert!(
            (p1.mean[i] - p2.mean[i]).abs() < 1e-8,
            "engine-backed gram changed predictions at {i}"
        );
    }
}
