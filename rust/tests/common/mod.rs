//! Shared fixtures for the integration suites: synthetic datasets, MKA
//! test configs, request builders, router/TCP bring-up and the job-poll
//! loop — the pieces previously duplicated across `gp_integration.rs`,
//! `sharded.rs`, `train_integration.rs` and `obs_integration.rs`.
//!
//! Each suite pulls this in with `mod common;`; unused helpers per
//! binary are expected, hence the file-level `allow(dead_code)`.
#![allow(dead_code)]

use std::sync::Arc;

use mka_gp::coordinator::{Client, JobState, Router, Server, ServiceConfig};
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::data::Dataset;
use mka_gp::mka::MkaConfig;
use mka_gp::util::Json;

/// Relative tolerance for compressed-vs-exact agreement (log-marginals,
/// evidence values) shared by the equivalence suites.
pub const REL_TOL: f64 = 0.10;

/// Default noise level the integration fixtures fit at.
pub const SIGMA2: f64 = 0.1;

/// A smooth synthetic GP dataset by (name, n, dim, seed) — the one-line
/// wrapper every suite was writing by hand.
pub fn synth(name: &str, n: usize, dim: usize, seed: u64) -> Dataset {
    gp_dataset(&SynthSpec::named(name, n, dim), seed)
}

/// Small MKA config for fast integration fits; `n_threads: 0` keeps the
/// global pool setting.
pub fn small_cfg(n_threads: usize) -> MkaConfig {
    MkaConfig { d_core: 16, block_size: 32, n_threads, ..MkaConfig::default() }
}

/// A router wired for tests: zero batching window (predicts dispatch
/// immediately) and a small worker pool.
pub fn test_router() -> Router {
    Router::new(test_config())
}

pub fn test_config() -> ServiceConfig {
    ServiceConfig { port: 0, batch_window_ms: 0, n_workers: 2, ..Default::default() }
}

/// Router behind a real TCP socket on an ephemeral port, plus a
/// connected client. Drop order (client, then server) closes cleanly.
pub fn tcp_rig(cfg: ServiceConfig) -> (Server, Client, Arc<Router>) {
    let router = Arc::new(Router::new(cfg));
    let server = Server::start(Arc::clone(&router), "127.0.0.1", 0).unwrap();
    let client = Client::connect(&server.addr().to_string()).unwrap();
    (server, client, router)
}

/// A `fit` request for `data` with the standard test hyperparameters.
/// Callers layer extras (`"shards"`, `"async"`) with `.with(...)`.
pub fn fit_json(model: &str, method: &str, data: &Dataset, k: usize) -> Json {
    Json::obj()
        .with("op", Json::Str("fit".into()))
        .with("model", Json::Str(model.into()))
        .with("method", Json::Str(method.into()))
        .with("x", matrix_json(data))
        .with("y", Json::from_f64_slice(&data.y))
        .with(
            "params",
            Json::obj()
                .with("lengthscale", Json::Num(1.0))
                .with("sigma2", Json::Num(SIGMA2))
                .with("k", Json::Num(k as f64)),
        )
}

/// A `predict` request at the given test rows.
pub fn predict_json(model: &str, rows: &[&[f64]]) -> Json {
    Json::obj()
        .with("op", Json::Str("predict".into()))
        .with("model", Json::Str(model.into()))
        .with("x", Json::Arr(rows.iter().map(|r| Json::from_f64_slice(r)).collect()))
}

/// An `observe` request appending `(xb, yb)` to a served model.
pub fn observe_json(model: &str, xb: &[&[f64]], yb: &[f64]) -> Json {
    Json::obj()
        .with("op", Json::Str("observe".into()))
        .with("model", Json::Str(model.into()))
        .with("x", Json::Arr(xb.iter().map(|r| Json::from_f64_slice(r)).collect()))
        .with("y", Json::from_f64_slice(yb))
}

/// The dataset's design matrix as protocol JSON (`[[...]...]`).
pub fn matrix_json(data: &Dataset) -> Json {
    Json::Arr((0..data.n()).map(|i| Json::from_f64_slice(data.x.row(i))).collect())
}

/// Poll an async job to completion through the `job` op, panicking on
/// failure or timeout; returns the terminal `job` response (with any
/// detail the job attached).
pub fn poll_job_done(r: &Router, job_id: u64) -> Json {
    for _ in 0..600 {
        let poll = r.handle(
            &Json::obj()
                .with("op", Json::Str("job".into()))
                .with("job_id", Json::Num(job_id as f64)),
        );
        match poll.str_field("state") {
            Some("done") => return poll,
            Some("failed") => panic!("job {job_id} failed: {poll:?}"),
            _ => std::thread::sleep(std::time::Duration::from_millis(25)),
        }
    }
    panic!("job {job_id} never finished");
}

/// Assert a router response succeeded, with the full response in the
/// panic message when it did not.
pub fn assert_ok(resp: &Json) {
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
}

/// The raw job state, for tests asserting non-terminal phases.
pub fn job_state(r: &Router, job_id: u64) -> JobState {
    r.jobs.get(job_id).expect("job exists").1
}
