//! Integration tests for the marginal-likelihood training plane:
//!
//! * the MKA-path MLL matches the dense Cholesky evidence (exactly when
//!   the core holds everything, closely under compression);
//! * the Nyström/Woodbury and PITC/block-Woodbury forms match their
//!   dense n×n equivalents to solver precision;
//! * the optimizer recovers planted (lengthscale, σ²) from GP draws;
//! * the coordinator serves `train` asynchronously: job id immediately,
//!   Queued→Running→Done with an eval trace, and the published model
//!   answers `predict`.

use mka_gp::baselines::nystrom::{select_landmarks, LandmarkMethod, NystromBlocks};
use mka_gp::coordinator::JobState;
use mka_gp::data::dataset::Dataset;
use mka_gp::data::synth::{gp_dataset, gp_prior_draw, latent_features, SynthSpec};
use mka_gp::experiments::methods::Method;
use mka_gp::gp::cv::HyperParams;
use mka_gp::kernels::{Kernel, RbfKernel};
use mka_gp::la::blas::{dot, gemm_tn};
use mka_gp::la::chol::Chol;
use mka_gp::la::dense::Mat;
use mka_gp::mka::MkaConfig;
use mka_gp::train::mll;
use mka_gp::train::{
    log_marginal_likelihood, maximize_mll, select_hyperparams, ModelSelection, OptimBudget,
    SearchBox,
};
use mka_gp::util::{Json, Rng};

mod common;
use common::{assert_ok, matrix_json, poll_job_done, predict_json, synth, test_router};

/// Dense reference evidence: −½yᵀC⁻¹y − ½ log det C − (n/2) log 2π.
fn dense_mll(c: &Mat, y: &[f64]) -> f64 {
    let chol = Chol::new(c).expect("dense covariance must be PD");
    let alpha = chol.solve(y);
    mll::gaussian_mll(dot(y, &alpha), chol.logdet(), y.len())
}

#[test]
fn mka_mll_matches_dense_cholesky() {
    let data = gp_dataset(&SynthSpec::named("mkamll", 90, 2), 3);
    let kern = RbfKernel::new(1.0);
    let s2 = 0.1;
    // Dense reference on K + σ²I.
    let mut k = kern.gram_sym(&data.x);
    k.add_diag(s2);
    let exact = dense_mll(&k, &data.y);

    // Core holds everything ⇒ the factorization is exact ⇒ the MLL is too.
    let lossless = MkaConfig { d_core: 128, block_size: 48, ..MkaConfig::default() };
    let v = mll::mll_mka(&data, &kern, s2, &lossless).unwrap();
    assert!(
        (v - exact).abs() < 1e-6 * exact.abs().max(1.0),
        "lossless MKA MLL {v} vs dense {exact}"
    );

    // Moderate compression tracks the dense value closely.
    let compressed =
        MkaConfig { d_core: 60, block_size: 45, gamma: 0.7, ..MkaConfig::default() };
    let va = mll::mll_mka(&data, &kern, s2, &compressed).unwrap();
    assert!(
        (va - exact).abs() < 0.10 * exact.abs(),
        "compressed MKA MLL {va} vs dense {exact}"
    );
}

#[test]
fn sor_and_fitc_woodbury_match_dense() {
    let data = gp_dataset(&SynthSpec::named("wood", 60, 2), 5);
    let n = data.n();
    let kern = RbfKernel::new(1.1);
    let s2 = 0.08;
    let z = select_landmarks(&data.x, 12, LandmarkMethod::Uniform, 9);
    let nb = NystromBlocks::new(&data, &kern, z).unwrap();

    // Dense Q = K_zfᵀ W⁻¹ K_zf through the same (jittered) W factor.
    let winv_kzf = nb.w_chol.solve_mat(&nb.kzf); // m×n
    let q = gemm_tn(&nb.kzf, &winv_kzf); // n×n

    // SoR: Λ = σ²I.
    let mut c_sor = q.clone();
    c_sor.symmetrize();
    c_sor.add_diag(s2);
    let dense_sor = dense_mll(&c_sor, &data.y);
    let fast_sor = mll::woodbury_mll(&nb, &data.y, &vec![s2; n]).unwrap();
    assert!(
        (fast_sor - dense_sor).abs() < 1e-6 * dense_sor.abs().max(1.0),
        "SoR Woodbury {fast_sor} vs dense {dense_sor}"
    );

    // FITC: Λ = diag(K − Q) + σ²I (same clamping as the model).
    let qd = nb.q_diag();
    let lam: Vec<f64> = (0..n)
        .map(|i| (kern.diag(data.x.row(i)) - qd[i]).max(0.0) + s2)
        .collect();
    let mut c_fitc = q.clone();
    c_fitc.symmetrize();
    for i in 0..n {
        c_fitc.set(i, i, c_fitc.at(i, i) + lam[i]);
    }
    let dense_fitc = dense_mll(&c_fitc, &data.y);
    let fast_fitc = mll::woodbury_mll(&nb, &data.y, &lam).unwrap();
    assert!(
        (fast_fitc - dense_fitc).abs() < 1e-6 * dense_fitc.abs().max(1.0),
        "FITC Woodbury {fast_fitc} vs dense {dense_fitc}"
    );
}

#[test]
fn pitc_block_woodbury_matches_dense() {
    let data = gp_dataset(&SynthSpec::named("pitcw", 60, 2), 7);
    let n = data.n();
    let kern = RbfKernel::new(1.0);
    let s2 = 0.1;
    let z = select_landmarks(&data.x, 10, LandmarkMethod::Uniform, 11);
    let nb = NystromBlocks::new(&data, &kern, z).unwrap();
    let clusters = mll::pitc_clusters(&data.x, 15, 11);

    // Dense C = Q + blockdiag(K_bb − Q_bb) + σ²I from the same partition.
    let winv_kzf = nb.w_chol.solve_mat(&nb.kzf);
    let mut c = gemm_tn(&nb.kzf, &winv_kzf);
    c.symmetrize();
    for members in &clusters {
        let kbb = kern.gram_sym(&data.x.gather_rows(members));
        let qbb = nb.q_block(members, members);
        for (bi, &i) in members.iter().enumerate() {
            for (bj, &j) in members.iter().enumerate() {
                let corr = 0.5 * (kbb.at(bi, bj) + kbb.at(bj, bi))
                    - 0.5 * (qbb.at(bi, bj) + qbb.at(bj, bi));
                c.set(i, j, c.at(i, j) + corr);
            }
        }
    }
    c.symmetrize();
    c.add_diag(s2);
    let dense = dense_mll(&c, &data.y);
    let fast = mll::block_woodbury_mll(&nb, &data, &kern, s2, &clusters).unwrap();
    assert!(
        (fast - dense).abs() < 1e-5 * dense.abs().max(1.0),
        "PITC block-Woodbury {fast} vs dense {dense}"
    );
}

/// Acceptance pin (noise-shift plane): an MKA evidence run whose path
/// revisits a cached length scale performs strictly fewer factorizations
/// than evidence evaluations. Each Nelder–Mead start's initial simplex
/// perturbs σ² at the start's ℓ, so at least one hit per start is
/// structural, not incidental. The per-run cache counts its own builds
/// (immune to concurrent tests); the process-wide `factorize_count()`
/// observable must have moved by at least those builds.
#[test]
fn mka_training_factorizes_less_than_it_evaluates() {
    // A single start keeps the factorization count fully deterministic
    // (no cross-start build races); its initial simplex alone revisits
    // the start's ℓ for the σ² vertex.
    let data = gp_dataset(&SynthSpec::named("cachetrain", 100, 2), 4);
    let sel =
        ModelSelection::Mll { budget: OptimBudget { max_evals: 20, n_starts: 1, tol: 1e-5 } };
    let before = mka_gp::mka::factorize_count();
    let report = select_hyperparams(Method::Mka, &data, &sel, 12, 3).unwrap();
    let fx = report.factorizations.expect("evidence path reports factorizations");
    assert!(report.evals >= 3, "at least the initial simplex, got {}", report.evals);
    assert!(fx >= 1, "at least one factor build");
    assert!(
        fx < report.evals,
        "σ²-revisits must be free: {fx} factorizations for {} evals",
        report.evals
    );
    // Global observable: monotone, and moved by at least this run's builds
    // (other tests may factorize concurrently, so only a lower bound).
    assert!(mka_gp::mka::factorize_count() >= before + fx as u64);
    // The job-facing JSON carries the economics.
    assert_eq!(report.to_json().num_field("factorizations"), Some(fx as f64));
}

#[test]
fn optimizer_recovers_planted_hyperparams() {
    // Plant a GP draw with known (ℓ, σ²) — no normalization, so the
    // planted noise level survives — and maximize the exact evidence.
    let mut rng = Rng::new(17);
    let x = latent_features(150, 2, 3, &mut rng);
    let ell_true = 1.2;
    let sigma_true = 0.3; // σ² = 0.09
    let f = gp_prior_draw(&x, ell_true, &mut rng);
    let y: Vec<f64> = f.iter().map(|&v| v + sigma_true * rng.normal()).collect();
    let data = Dataset::new("planted", x, y);

    // 60 evals per start: the mixture-cluster evidence surface needs a
    // real budget — 30/start reliably stalls on worse-than-planted optima.
    let budget = OptimBudget { max_evals: 180, n_starts: 3, tol: 1e-6 };
    let sbox = SearchBox::for_dim(2);
    let out = maximize_mll(
        |hp| log_marginal_likelihood(Method::Full, &data, hp, 16, 1).ok(),
        2,
        &budget,
        &sbox,
    )
    .unwrap();

    let s2_true = sigma_true * sigma_true;
    assert!(
        out.best.lengthscale > ell_true / 2.0 && out.best.lengthscale < ell_true * 2.0,
        "recovered lengthscale {} vs planted {ell_true}",
        out.best.lengthscale
    );
    assert!(
        out.best.sigma2 > s2_true / 3.0 && out.best.sigma2 < s2_true * 3.0,
        "recovered sigma2 {} vs planted {s2_true}",
        out.best.sigma2
    );
    // The optimum must be at least as good as the planted point itself.
    let planted = log_marginal_likelihood(
        Method::Full,
        &data,
        HyperParams { lengthscale: ell_true, sigma2: s2_true },
        16,
        1,
    )
    .unwrap();
    assert!(
        out.best_mll >= planted - 1e-6,
        "best {} < planted {planted}",
        out.best_mll
    );
}

#[test]
fn coordinator_ard_train_job_lifecycle() {
    // The gradient path end-to-end: async {"op":"train"} with
    // "selection": "mll-grad", "ard": true learns per-dimension length
    // scales, surfaces them in the job detail, and publishes a serving
    // model fitted with the ARD kernel.
    let r = test_router();
    let data = synth("coord-ard", 90, 2, 8);
    let req = Json::obj()
        .with("op", Json::Str("train".into()))
        .with("model", Json::Str("m-ard".into()))
        .with("method", Json::Str("sor".into()))
        .with("x", matrix_json(&data))
        .with("y", Json::from_f64_slice(&data.y))
        .with("selection", Json::Str("mll-grad".into()))
        .with("ard", Json::Bool(true))
        .with(
            "budget",
            Json::obj().with("max_evals", Json::Num(20.0)).with("n_starts", Json::Num(2.0)),
        )
        .with("params", Json::obj().with("k", Json::Num(10.0)));
    let resp = r.handle(&req);
    assert_ok(&resp);
    let job_id = resp.usize_field("job_id").expect("job_id") as u64;

    let done = poll_job_done(&r, job_id);
    let train = done.get("train").expect("train detail");
    assert_eq!(train.str_field("selection"), Some("mll-grad"));
    let ells = train.get("lengthscales").expect("per-dimension scales").f64_array().unwrap();
    assert_eq!(ells.len(), 2);
    assert!(ells.iter().all(|l| l.is_finite() && *l > 0.0));
    assert!(train.num_field("best_mll").unwrap().is_finite());

    let pred = r.handle(&predict_json("m-ard", &[&[0.2, -0.1]]));
    assert_ok(&pred);
    assert_eq!(pred.get("mean").unwrap().f64_array().unwrap().len(), 1);
}

#[test]
fn coordinator_train_job_lifecycle() {
    let r = test_router();
    let data = synth("coord", 120, 2, 2);
    let req = Json::obj()
        .with("op", Json::Str("train".into()))
        .with("model", Json::Str("m-train".into()))
        .with("method", Json::Str("mka".into()))
        .with("x", matrix_json(&data))
        .with("y", Json::from_f64_slice(&data.y))
        .with("selection", Json::Str("mll".into()))
        .with(
            "budget",
            Json::obj().with("max_evals", Json::Num(16.0)).with("n_starts", Json::Num(2.0)),
        )
        .with("params", Json::obj().with("k", Json::Num(12.0)));

    // Async by default: a job id comes back immediately, before Done.
    let resp = r.handle(&req);
    assert_ok(&resp);
    let job_id = resp.usize_field("job_id").expect("job_id") as u64;
    let first = r.jobs.get(job_id).unwrap().1;
    assert!(
        matches!(first, JobState::Queued | JobState::Running),
        "job already terminal at submit time: {first:?}"
    );

    // Poll through the job op until done.
    let done = poll_job_done(&r, job_id);

    // The job report carries the optimization result and trace.
    let train = done.get("train").expect("train detail");
    assert!(train.num_field("best_mll").unwrap().is_finite());
    assert!(train.num_field("evals").unwrap() >= 2.0);
    assert!(train.num_field("secs").unwrap() >= 0.0);
    let best = train.get("best").unwrap();
    assert!(best.num_field("lengthscale").unwrap() > 0.0);
    assert!(best.num_field("sigma2").unwrap() > 0.0);
    let trace = train.get("trace").unwrap().as_arr().unwrap();
    assert!(!trace.is_empty());
    for e in trace {
        assert!(e.num_field("value").unwrap().is_finite());
    }

    // The optimized model serves predictions.
    let pred = r.handle(&predict_json("m-train", &[&[0.1, -0.3], &[0.5, 0.2]]));
    assert_ok(&pred);
    assert_eq!(pred.get("mean").unwrap().f64_array().unwrap().len(), 2);

    // Metrics surface the training plane.
    let m = r.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
    assert!(m.get("counters").unwrap().num_field("trains").unwrap_or(0.0) >= 1.0);
    let hists = m.get("histograms").unwrap();
    assert!(hists.get("train.secs").is_some());
    assert!(hists.get("train.evals").is_some());
    assert!(hists.get("train.best_mll").is_some());
}
