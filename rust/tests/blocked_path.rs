//! Property tests for the blocked (multi-RHS) execution path: on random
//! factors from the full kernel/config family, `matmat` / `solve_mat` /
//! `pow_apply_mat` must agree column-for-column with the per-vector
//! `matvec` / `solve` / `pow_apply` cascades, and the column-parallel
//! variants must agree with the serial blocked ones.

use mka_gp::compress::{CompressorKind, QFactor};
use mka_gp::kernels::{Kernel, LaplaceKernel, Matern32Kernel, RbfKernel};
use mka_gp::la::{Givens, GivensSeq, Mat};
use mka_gp::mka::{factorize, BlockFactor, MkaConfig, MkaFactor, Stage};
use mka_gp::util::Rng;

/// Random kernel matrix + points: varied n, d, lengthscale, kernel family
/// (mirrors tests/properties.rs).
fn random_kernel(seed: u64) -> (Mat, Mat) {
    let mut rng = Rng::new(seed);
    let n = 40 + rng.below(120); // 40..160
    let d = 1 + rng.below(5);
    let ell = rng.uniform_in(0.3, 2.5);
    let sigma2 = rng.uniform_in(0.02, 0.4);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * rng.uniform_in(0.5, 2.0));
    let kern: Box<dyn Kernel> = match rng.below(3) {
        0 => Box::new(RbfKernel::new(ell)),
        1 => Box::new(LaplaceKernel::new(ell)),
        _ => Box::new(Matern32Kernel::new(ell)),
    };
    let mut k = kern.gram_sym(&x);
    k.add_diag(sigma2);
    (k, x)
}

fn random_config(seed: u64, n: usize) -> MkaConfig {
    let mut rng = Rng::new(seed ^ 0xb10cced);
    MkaConfig {
        d_core: 8 + rng.below(24),
        block_size: (16 + rng.below(48)).min(n).max(2),
        gamma: rng.uniform_in(0.35, 0.7),
        compressor: match rng.below(3) {
            0 => CompressorKind::Mmf,
            1 => CompressorKind::Spca,
            _ => CompressorKind::Evd,
        },
        seed,
        n_threads: 1 + rng.below(3),
        ..MkaConfig::default()
    }
}

const TRIALS: u64 = 10;
/// Acceptance tolerance: blocked and per-vector paths run the same
/// rotations in the same order; only the core GEMM/GEMV summation order
/// differs.
const TOL: f64 = 1e-10;

#[test]
fn prop_matmat_matches_per_column_matvec() {
    for seed in 0..TRIALS {
        let (k, x) = random_kernel(seed + 2000);
        let cfg = random_config(seed + 2000, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        let mut rng = Rng::new(seed * 17 + 3);
        let b = 1 + rng.below(12);
        let z = Mat::from_fn(k.rows, b, |_, _| rng.normal());
        let blocked = f.matmat(&z);
        for j in 0..b {
            let col = f.matvec(&z.col(j));
            for i in 0..k.rows {
                assert!(
                    (blocked.at(i, j) - col[i]).abs() < TOL,
                    "seed {seed} ({i},{j}): {} vs {}",
                    blocked.at(i, j),
                    col[i]
                );
            }
        }
    }
}

#[test]
fn prop_solve_mat_matches_per_column_solve() {
    for seed in 0..TRIALS {
        let (k, x) = random_kernel(seed + 3000);
        let cfg = random_config(seed + 3000, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        let mut rng = Rng::new(seed * 13 + 5);
        let b = 1 + rng.below(10);
        let z = Mat::from_fn(k.rows, b, |_, _| rng.normal());
        let blocked = f.solve_mat(&z).unwrap();
        for j in 0..b {
            let col = f.solve(&z.col(j)).unwrap();
            for i in 0..k.rows {
                assert!(
                    (blocked.at(i, j) - col[i]).abs() < TOL,
                    "seed {seed} ({i},{j})"
                );
            }
        }
    }
}

#[test]
fn prop_par_variants_match_serial_blocked() {
    for seed in 0..6 {
        let (k, x) = random_kernel(seed + 4000);
        let cfg = random_config(seed + 4000, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        let mut rng = Rng::new(seed + 77);
        // Wide block so the parallel split actually engages.
        let z = Mat::from_fn(k.rows, 48, |_, _| rng.normal());
        for threads in [2, 4, 7] {
            let mm = f.matmat_par(&z, threads).sub(&f.matmat(&z)).max_abs();
            assert!(mm < 1e-12, "seed {seed} threads {threads}: matmat {mm}");
            let sm = f
                .solve_mat_par(&z, threads)
                .unwrap()
                .sub(&f.solve_mat(&z).unwrap())
                .max_abs();
            assert!(sm < 1e-12, "seed {seed} threads {threads}: solve {sm}");
        }
    }
}

#[test]
fn prop_pow_exp_mat_match_vector_paths() {
    for seed in 0..6 {
        let (k, x) = random_kernel(seed + 5000);
        let cfg = random_config(seed + 5000, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        let mut rng = Rng::new(seed + 88);
        let z = Mat::from_fn(k.rows, 5, |_, _| rng.normal());
        let powm = f.pow_apply_mat(0.5, &z);
        let expm = f.exp_apply_mat(0.1, &z);
        for j in 0..5 {
            let pv = f.pow_apply(0.5, &z.col(j));
            let ev = f.exp_apply(0.1, &z.col(j));
            for i in 0..k.rows {
                assert!((powm.at(i, j) - pv[i]).abs() < TOL, "pow seed {seed}");
                assert!((expm.at(i, j) - ev[i]).abs() < TOL, "exp seed {seed}");
            }
        }
    }
}

#[test]
fn blocked_to_dense_matches_serial_reconstruction() {
    for seed in 0..4 {
        let (k, x) = random_kernel(seed + 6000);
        let cfg = random_config(seed + 6000, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        // to_dense is now one blocked cascade over the identity; rebuild
        // the old way (n serial matvecs) and compare.
        let dense = f.to_dense();
        let n = f.n;
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = f.matvec(&e);
            e[j] = 0.0;
            for i in 0..n {
                assert!(
                    (dense.at(i, j) - col[i]).abs() < TOL,
                    "seed {seed} ({i},{j})"
                );
            }
        }
    }
}

/// Hand-built single-stage factor for the singularity / logdet edge cases
/// (mirrors the unit-test tiny factor but through the public API).
fn tiny_factor(dvals: Vec<f64>, core: Mat) -> MkaFactor {
    let mut seq = GivensSeq::new();
    seq.push(Givens::jacobi(0, 1, 3.0, 1.0, 2.0));
    let stage = Stage {
        n_in: 4,
        blocks: vec![
            BlockFactor { idx: vec![0, 1], q: QFactor::Givens(seq) },
            BlockFactor { idx: vec![2, 3], q: QFactor::Identity },
        ],
        core_global: vec![0, 2],
        wavelet_global: vec![1, 3],
        dvals,
    };
    MkaFactor::new(4, vec![stage], core)
}

#[test]
fn regression_relative_singularity_gate() {
    let good_core = Mat::from_rows(&[&[2.0, 0.3], &[0.3, 1.5]]);
    // Wavelet value 18 orders of magnitude under the spectral max: the
    // old absolute 1e-300 gate accepted this and solve returned garbage.
    let f = tiny_factor(vec![0.7, 1e-18], good_core.clone());
    assert!(f.solve(&[1.0; 4]).is_err());
    assert!(f.solve_mat(&Mat::eye(4)).is_err());
    assert!(f.logdet().is_err());
    // Well-conditioned spectrum passes.
    let ok = tiny_factor(vec![0.7, 0.9], good_core);
    assert!(ok.solve(&[1.0; 4]).is_ok());
    assert!(ok.logdet().is_ok());
}

#[test]
fn regression_logdet_errors_on_negative_spectrum() {
    let core = Mat::from_rows(&[&[2.0, 0.3], &[0.3, 1.5]]);
    let f = tiny_factor(vec![0.7, -0.9], core);
    // Old behaviour: silently summed ln|d| and returned a finite, wrong
    // marginal-likelihood term.
    assert!(f.logdet().is_err());
    // The signed operator algebra itself stays usable.
    assert!(f.det().is_finite());
    assert!(f.matvec(&[1.0; 4]).iter().all(|v| v.is_finite()));
}
