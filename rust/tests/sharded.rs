//! Integration tests for the sharded GP serving plane: 1-shard
//! bit-identity with the monolithic cascade, k-shard bit-determinism at
//! any thread count, the router's `shards` lifecycle, and the typed
//! errors guarding it.

use std::sync::Arc;

use mka_gp::cluster::ClusterMethod;
use mka_gp::experiments::methods::mka_config_for;
use mka_gp::gp::mka_gp::MkaGp;
use mka_gp::gp::sharded::ShardedGp;
use mka_gp::gp::GpModel;
use mka_gp::kernels::RbfKernel;
use mka_gp::util::Json;

mod common;
use common::{assert_ok, fit_json, predict_json, synth, test_router};

/// The single-expert passthrough: a 1-shard fleet built through the
/// serving-plane entry points is bit-identical to a plain `MkaGp` on the
/// same config (the acceptance gate for the refactor being a refactor).
#[test]
fn one_shard_fleet_is_bit_identical_to_plain_mka() {
    let data = synth("sh-one", 160, 3, 11);
    let (tr, te) = data.split(0.9, 2);
    let kern = RbfKernel::new(1.1);
    let cfg = mka_config_for(16, tr.n(), 7);
    let plain = MkaGp::fit(&tr, &kern, 0.1, &cfg).unwrap();
    let fleet = ShardedGp::fit(&tr, &kern, 0.1, &cfg, 1, ClusterMethod::KMeans).unwrap();
    assert_eq!(fleet.n_shards(), 1);
    let pp = plain.predict(&te.x);
    let pf = fleet.predict(&te.x);
    for i in 0..te.n() {
        assert_eq!(pp.mean[i].to_bits(), pf.mean[i].to_bits(), "mean[{i}]");
        assert_eq!(pp.var[i].to_bits(), pf.var[i].to_bits(), "var[{i}]");
    }
}

/// PR-2's determinism contract survives the fleet: fit + predict with
/// k shards produces bit-identical posteriors at 1, 2 and 4 threads.
#[test]
fn sharded_fit_predict_bit_deterministic_across_threads() {
    let data = synth("sh-det", 200, 2, 13);
    let (tr, te) = data.split(0.9, 3);
    let kern = RbfKernel::new(0.9);
    let cfg = mka_config_for(12, tr.n(), 5);
    let run = || {
        let fleet =
            ShardedGp::fit(&tr, &kern, 0.1, &cfg, 3, ClusterMethod::KMeans).unwrap();
        let p = fleet.predict(&te.x);
        let bits: Vec<u64> =
            p.mean.iter().chain(p.var.iter()).map(|v| v.to_bits()).collect();
        (fleet.n_shards(), fleet.shard_sizes(), bits)
    };
    mka_gp::par::set_threads(1);
    let a = run();
    mka_gp::par::set_threads(2);
    let b = run();
    mka_gp::par::set_threads(4);
    let c = run();
    assert!(a.0 >= 2, "partition should produce several shards");
    assert_eq!(a, b, "2-thread run diverged from serial");
    assert_eq!(a, c, "4-thread run diverged from serial");
}

/// Full `shards` lifecycle through the router: sharded fit, metadata in
/// `models`, routed predict, O(shards) retune, shard metrics.
#[test]
fn router_shards_lifecycle() {
    let router = Arc::new(test_router());
    let data = synth("sh-life", 120, 2, 17);
    let (tr, te) = data.split(0.9, 4);

    let resp = router.handle(&fit_json("fleet", "mka", &tr, 12).with("shards", Json::Num(3.0)));
    assert_ok(&resp);
    assert!(resp.usize_field("shards").unwrap_or(0) >= 2, "{resp:?}");

    let resp = router.handle(&Json::obj().with("op", Json::Str("models".into())));
    let models = resp.get("models").unwrap().as_arr().unwrap();
    let entry = models
        .iter()
        .find(|m| m.str_field("name") == Some("fleet"))
        .expect("fleet listed");
    assert!(entry.str_field("method").unwrap().starts_with("Sharded-MKA"));
    let sizes = entry.get("shard_sizes").unwrap().f64_array().unwrap();
    assert_eq!(sizes.iter().sum::<f64>() as usize, tr.n());

    let rows: Vec<&[f64]> = (0..te.n()).map(|i| te.x.row(i)).collect();
    let resp = router.handle(&predict_json("fleet", &rows));
    assert_ok(&resp);
    assert_eq!(resp.get("mean").unwrap().f64_array().unwrap().len(), te.n());

    let resp = router.handle(
        &Json::obj()
            .with("op", Json::Str("retune".into()))
            .with("model", Json::Str("fleet".into()))
            .with("sigma2", Json::Num(0.3)),
    );
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");

    let resp = router.handle(&Json::obj().with("op", Json::Str("metrics".into())));
    let shard = resp.get("shard").expect("shard metrics section");
    assert!(shard.num_field("count").unwrap() >= 2.0);
    assert!(shard.num_field("route_hits").unwrap() >= 1.0);
}

/// The typed errors guarding the shards field: zero, more shards than
/// points, and shards on a non-MKA method are all refused up front.
#[test]
fn shard_errors_are_typed() {
    let router = Arc::new(test_router());
    let data = synth("sh-err", 60, 2, 19);

    let resp = router.handle(&fit_json("z", "mka", &data, 8).with("shards", Json::Num(0.0)));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    assert!(resp.str_field("error").unwrap().contains("shards"), "{resp:?}");

    let resp = router.handle(&fit_json("z", "sor", &data, 8).with("shards", Json::Num(2.0)));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    assert!(resp.str_field("error").unwrap().contains("mka"), "{resp:?}");

    let resp = router.handle(&fit_json("z", "mka", &data, 8).with("shards", Json::Num(61.0)));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");

    // library layer: the partition itself validates the same bounds
    assert!(mka_gp::gp::sharded::shard_partition(
        &data.x,
        0,
        ClusterMethod::KMeans,
        1
    )
    .is_err());
    assert!(mka_gp::gp::sharded::shard_partition(
        &data.x,
        data.n() + 1,
        ClusterMethod::KMeans,
        1
    )
    .is_err());
}

/// Sharded `train` sums per-shard evidences and reports per-shard
/// factorization counts; the published model is the sharded fleet.
#[test]
fn sharded_train_reports_per_shard_factorizations() {
    use mka_gp::experiments::methods::Method;
    use mka_gp::train::{ModelSelection, OptimBudget};

    let data = synth("sh-train", 140, 2, 23);
    let sel = ModelSelection::Mll {
        budget: OptimBudget { max_evals: 10, n_starts: 1, tol: 1e-4 },
    };
    let (model, report) = mka_gp::train::train_model_sharded(
        Method::Mka,
        &data,
        &sel,
        10,
        7,
        2,
        ClusterMethod::KMeans,
    )
    .unwrap();
    let per_shard = report.shard_factorizations.expect("per-shard counts");
    assert!(!per_shard.is_empty());
    assert_eq!(per_shard.iter().sum::<usize>(), report.factorizations.unwrap());
    assert!(model.info().shards >= 2);
}
