//! Integration tests for the serving coordinator: full TCP round trips,
//! async job lifecycle, batched prediction correctness vs direct calls.

use std::sync::Arc;

use mka_gp::coordinator::{Client, JobState, Router, Server, ServiceConfig};
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::gp::GpModel;
use mka_gp::util::Json;

fn boot() -> (Server, Arc<Router>, String) {
    let cfg = ServiceConfig { port: 0, n_workers: 2, batch_window_ms: 2, ..Default::default() };
    let router = Arc::new(Router::new(cfg));
    let server = Server::start(Arc::clone(&router), "127.0.0.1", 0).unwrap();
    let addr = format!("{}", server.addr());
    (server, router, addr)
}

fn fit_json(model: &str, method: &str, data: &mka_gp::data::Dataset, k: usize, is_async: bool) -> Json {
    let x: Vec<Json> = (0..data.n()).map(|i| Json::from_f64_slice(data.x.row(i))).collect();
    Json::obj()
        .with("op", Json::Str("fit".into()))
        .with("model", Json::Str(model.into()))
        .with("method", Json::Str(method.into()))
        .with("x", Json::Arr(x))
        .with("y", Json::from_f64_slice(&data.y))
        .with(
            "params",
            Json::obj()
                .with("lengthscale", Json::Num(0.8))
                .with("sigma2", Json::Num(0.1))
                .with("k", Json::Num(k as f64)),
        )
        .with("async", Json::Bool(is_async))
}

#[test]
fn full_lifecycle_over_tcp() {
    let (_server, router, addr) = boot();
    let data = gp_dataset(&SynthSpec::named("life", 150, 2), 1);
    let (tr, te) = data.split(0.9, 1);
    let mut c = Client::connect(&addr).unwrap();

    // sync fit
    let resp = c.call(&fit_json("m-sync", "sor", &tr, 12, false)).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    assert!(resp.num_field("fit_secs").unwrap() >= 0.0);

    // models listed as metadata objects
    let resp = c.call(&Json::obj().with("op", Json::Str("models".into()))).unwrap();
    let models = resp.get("models").unwrap().as_arr().unwrap();
    let entry = models
        .iter()
        .find(|m| m.str_field("name") == Some("m-sync"))
        .expect("m-sync listed");
    assert_eq!(entry.usize_field("n"), Some(tr.n()));
    assert_eq!(entry.usize_field("shards"), Some(1));

    // predict over TCP equals direct predict
    let x: Vec<Json> = (0..te.n()).map(|i| Json::from_f64_slice(te.x.row(i))).collect();
    let resp = c
        .call(
            &Json::obj()
                .with("op", Json::Str("predict".into()))
                .with("model", Json::Str("m-sync".into()))
                .with("x", Json::Arr(x)),
        )
        .unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    let tcp_mean = resp.get("mean").unwrap().f64_array().unwrap();
    let direct = router.registry.get("m-sync").unwrap().predict(&te.x);
    assert_eq!(tcp_mean.len(), direct.mean.len());
    for (a, b) in tcp_mean.iter().zip(&direct.mean) {
        assert!((a - b).abs() < 1e-9);
    }

    // drop model
    let resp = c
        .call(
            &Json::obj()
                .with("op", Json::Str("drop_model".into()))
                .with("model", Json::Str("m-sync".into())),
        )
        .unwrap();
    assert_eq!(resp.get("dropped"), Some(&Json::Bool(true)));
    assert!(router.registry.get("m-sync").is_none());
}

#[test]
fn async_fit_lifecycle() {
    let (_server, router, addr) = boot();
    let data = gp_dataset(&SynthSpec::named("async", 120, 2), 2);
    let mut c = Client::connect(&addr).unwrap();
    let resp = c.call(&fit_json("m-async", "mka", &data, 12, true)).unwrap();
    let job = resp.usize_field("job_id").expect("job_id") as u64;

    // poll until done
    let mut done = false;
    for _ in 0..300 {
        let resp = c
            .call(&Json::obj().with("op", Json::Str("job".into())).with("job_id", Json::Num(job as f64)))
            .unwrap();
        match resp.str_field("state") {
            Some("done") => {
                done = true;
                break;
            }
            Some("failed") => panic!("fit failed: {resp:?}"),
            _ => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    }
    assert!(done, "job never finished");
    assert!(matches!(router.jobs.get(job).unwrap().1, JobState::Done { .. }));
    assert!(router.registry.get("m-async").is_some());
}

#[test]
fn batching_counts_requests() {
    let (_server, router, addr) = boot();
    let data = gp_dataset(&SynthSpec::named("bat", 130, 2), 3);
    let mut c = Client::connect(&addr).unwrap();
    c.call(&fit_json("m-b", "sor", &data, 10, false)).unwrap();

    // several concurrent single-point predictions
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let addr = addr.clone();
            let row = data.x.row(i).to_vec();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let req = Json::obj()
                    .with("op", Json::Str("predict".into()))
                    .with("model", Json::Str("m-b".into()))
                    .with("x", Json::Arr(vec![Json::from_f64_slice(&row)]));
                c.call(&req).unwrap()
            })
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap().get("ok"), Some(&Json::Bool(true)));
    }
    assert_eq!(router.metrics.counter("predictions"), 6);
    assert!(router.metrics.counter("batches") >= 1);
}

#[test]
fn protocol_error_paths() {
    let (_server, _router, addr) = boot();
    let mut c = Client::connect(&addr).unwrap();
    // unknown op
    let resp = c.call(&Json::obj().with("op", Json::Str("bogus".into()))).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    // fit with mismatched shapes
    let bad = Json::parse(
        r#"{"op":"fit","model":"m","method":"sor","x":[[1.0,2.0]],"y":[1.0,2.0,3.0]}"#,
    )
    .unwrap();
    let resp = c.call(&bad).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    // predict against unknown model
    let resp = c
        .call(
            &Json::obj()
                .with("op", Json::Str("predict".into()))
                .with("model", Json::Str("ghost".into()))
                .with("x", Json::Arr(vec![Json::from_f64_slice(&[1.0])])),
        )
        .unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    // metrics include the error count
    let resp = c.call(&Json::obj().with("op", Json::Str("metrics".into()))).unwrap();
    let errors = resp.get("counters").and_then(|x| x.num_field("errors")).unwrap_or(0.0);
    assert!(errors >= 3.0, "errors counter {errors}");
}

#[test]
fn config_layering_env_and_map() {
    let mut cfg = ServiceConfig::default();
    std::env::set_var("MKA_GP_PORT", "9191");
    std::env::set_var("MKA_GP_COMPRESSOR", "evd");
    cfg.apply_env().unwrap();
    std::env::remove_var("MKA_GP_PORT");
    std::env::remove_var("MKA_GP_COMPRESSOR");
    assert_eq!(cfg.port, 9191);
    assert_eq!(cfg.compressor, "evd");
    // CLI-style overrides win
    let mut kv = std::collections::BTreeMap::new();
    kv.insert("port".to_string(), "9009".to_string());
    cfg.apply(&kv).unwrap();
    assert_eq!(cfg.port, 9009);
}
