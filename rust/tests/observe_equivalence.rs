//! Refit-equivalence pins for the streaming observe plane.
//!
//! Four contracts, each against a fresh `fit` on the concatenated data:
//!
//! 1. **Incremental tracking** — `observe(batch)` serves predictions
//!    within tight relative tolerance of a fresh fit on all points, and
//!    its (approximate) evidence stays within the compression tolerance.
//! 2. **Gated refit is exact** — when the drift gate forces the windowed
//!    full re-fit, the updated model is *bit-identical* to a fresh fit:
//!    same prediction bits, same log-marginal bits.
//! 3. **Thread determinism** — the whole observe pipeline (fit → extend
//!    → predict) produces bit-identical results at 1, 2 and 4 threads.
//! 4. **Stage-reuse accounting** — the incremental path performs zero
//!    new full factorizations (`factorize_count` is flat across it) and
//!    rebuilds strictly fewer stages than the factor holds, with the
//!    process-wide stage counters moving by exactly the per-call stats.
//!
//! The assertion surface includes process-global counters, so every
//! test serializes on one lock — unlike the lib unit tests, which must
//! tolerate concurrent factorizations and only pin per-call stats.

use std::sync::Mutex;

use mka_gp::data::Dataset;
use mka_gp::gp::mka_gp::MkaGp;
use mka_gp::gp::{GpModel, ObservePath, ObservePolicy};
use mka_gp::kernels::RbfKernel;
use mka_gp::la::dense::Mat;
use mka_gp::mka::{factorize_count, stage_rebuild_count, stage_reuse_count, MkaConfig};

mod common;
use common::{synth, REL_TOL, SIGMA2};

static LOCK: Mutex<()> = Mutex::new(());

/// Serialize counter-sensitive tests; survive a poisoned lock (a failed
/// test must not cascade into spurious failures of the rest).
fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Small config with several compression stages so stage reuse is
/// observable; serial so bitwise claims are about the math, not a pool.
fn cfg(n_threads: usize) -> MkaConfig {
    MkaConfig { d_core: 12, block_size: 32, n_threads, ..MkaConfig::default() }
}

/// Split the last `b` rows off as the streaming batch.
fn split_tail(data: &Dataset, b: usize) -> (Dataset, Mat, Vec<f64>) {
    let n = data.n() - b;
    let head: Vec<usize> = (0..n).collect();
    let tail: Vec<usize> = (n..data.n()).collect();
    let older = Dataset::new(data.name.clone(), data.x.gather_rows(&head), data.y[..n].to_vec());
    (older, data.x.gather_rows(&tail), data.y[n..].to_vec())
}

/// The dataset a fresh fit on "all points" sees: old rows then the
/// batch, in arrival order — the same convention `observe` appends in.
fn concat(older: &Dataset, xb: &Mat, yb: &[f64]) -> Dataset {
    let n = older.n();
    let mut x = Mat::zeros(n + xb.rows, older.dim());
    x.set_block(0, 0, &older.x);
    x.set_block(n, 0, xb);
    let mut y = older.y.clone();
    y.extend_from_slice(yb);
    Dataset::new(older.name.clone(), x, y)
}

fn test_grid(dim: usize) -> Mat {
    Mat::from_fn(9, dim, |i, j| -0.8 + 0.2 * i as f64 + 0.05 * j as f64)
}

fn assert_rel_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for i in 0..a.len() {
        let denom = b[i].abs().max(1e-9);
        assert!(
            (a[i] - b[i]).abs() <= tol * denom,
            "{what}[{i}]: {} vs {} (rel tol {tol})",
            a[i],
            b[i]
        );
    }
}

fn assert_bits_equal(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), b[i].to_bits(), "{what}[{i}]: {} vs {}", a[i], b[i]);
    }
}

/// Contract 1 + 4: incremental observe tracks a fresh fit on all points
/// and does it without a single new full factorization — untouched
/// stages are shared, and rebuilds stay strictly below the stage count.
#[test]
fn incremental_observe_tracks_fresh_fit_without_refactorizing() {
    let _g = lock();
    let data = synth("oe-inc", 144, 2, 5);
    let (older, xb, yb) = split_tail(&data, 16);
    let c = cfg(1);
    let base = MkaGp::fit(&older, &RbfKernel::new(0.8), SIGMA2, &c).unwrap();
    // Force the training factor now so the deltas below isolate the
    // observe call itself.
    assert!(base.log_marginal().unwrap().is_finite());

    let fx_before = factorize_count();
    let rebuilds_before = stage_rebuild_count();
    let reuses_before = stage_reuse_count();
    let (obs, report) = base.observed(&xb, &yb, &ObservePolicy::default()).unwrap();

    // Accounting: the incremental path never runs a full factorization…
    assert_eq!(report.path, ObservePath::Incremental, "drift gate fired on smooth data");
    assert_eq!(
        factorize_count(),
        fx_before,
        "incremental observe must extend the stored factor, not refactorize"
    );
    // …and shares every untouched stage instead of rebuilding it.
    let stats = report.stats.expect("incremental path reports extend stats");
    assert_eq!(stats.appended, 16);
    assert!(
        stats.stages_rebuilt < stats.stages_total,
        "every stage rebuilt ({} of {}) — nothing was shared",
        stats.stages_rebuilt,
        stats.stages_total
    );
    assert!(stats.stages_reused >= 1, "no stage reused");
    assert!(stats.blocks_reused >= 1, "no block reused at stage 0");
    // The process-wide counters moved by exactly this call's stats.
    assert_eq!(stage_rebuild_count() - rebuilds_before, stats.stages_rebuilt as u64);
    assert_eq!(stage_reuse_count() - reuses_before, stats.stages_reused as u64);

    // Equivalence: predictions track a fresh fit on all points tightly
    // (the stored training set is identical, so the transductive
    // predict path sees the same joint gram)…
    let fresh = MkaGp::fit(&concat(&older, &xb, &yb), &RbfKernel::new(0.8), SIGMA2, &c).unwrap();
    let xt = test_grid(older.dim());
    let po = obs.predict(&xt);
    let pf = fresh.predict(&xt);
    assert_rel_close(&po.mean, &pf.mean, 1e-9, "mean");
    assert_rel_close(&po.var, &pf.var, 1e-9, "var");
    // …and the extended factor's evidence stays within the compression
    // tolerance of the fresh factor's.
    let lo = obs.log_marginal().unwrap();
    let lf = fresh.log_marginal().unwrap();
    assert!(
        (lo - lf).abs() <= REL_TOL * lf.abs().max(1.0),
        "extended-factor evidence {lo} drifted from fresh {lf}"
    );
}

/// Contract 2: when the drift gate fires (forced here with a tiny
/// threshold), the fallback is *exactly* a fresh fit — bit-identical
/// predictions and bit-identical log-marginal.
#[test]
fn gated_refit_is_bitwise_a_fresh_fit() {
    let _g = lock();
    let data = synth("oe-refit", 120, 2, 9);
    let (older, xb, yb) = split_tail(&data, 12);
    let c = cfg(1);
    let base = MkaGp::fit(&older, &RbfKernel::new(0.8), SIGMA2, &c).unwrap();
    let policy = ObservePolicy { drift_threshold: 1e-12, ..ObservePolicy::default() };
    let (obs, report) = base.observed(&xb, &yb, &policy).unwrap();
    assert_eq!(report.path, ObservePath::Refit);
    assert!(report.reason.as_deref().unwrap_or("").contains("drift"), "{:?}", report.reason);
    assert!(report.stats.is_none(), "refit path must not claim stage reuse");

    let fresh = MkaGp::fit(&concat(&older, &xb, &yb), &RbfKernel::new(0.8), SIGMA2, &c).unwrap();
    let xt = test_grid(older.dim());
    let po = obs.predict(&xt);
    let pf = fresh.predict(&xt);
    assert_bits_equal(&po.mean, &pf.mean, "mean");
    assert_bits_equal(&po.var, &pf.var, "var");
    assert_eq!(
        obs.log_marginal().unwrap().to_bits(),
        fresh.log_marginal().unwrap().to_bits(),
        "gated refit evidence must be bitwise the fresh fit's"
    );
}

/// Contract 2, windowed: with a window the gated refit keeps exactly
/// the most recent points and is bitwise a fresh fit on that window.
#[test]
fn windowed_refit_is_bitwise_a_fresh_fit_on_the_window() {
    let _g = lock();
    let data = synth("oe-win", 128, 2, 13);
    let (older, xb, yb) = split_tail(&data, 8);
    let c = cfg(1);
    let base = MkaGp::fit(&older, &RbfKernel::new(0.8), SIGMA2, &c).unwrap();
    let window = 48;
    let policy = ObservePolicy { drift_threshold: 1e-12, window, ..ObservePolicy::default() };
    let (obs, report) = base.observed(&xb, &yb, &policy).unwrap();
    assert_eq!(report.path, ObservePath::Refit);
    assert_eq!(report.n_total, window, "window not applied");

    // The window is the tail of (older ++ batch).
    let all = concat(&older, &xb, &yb);
    let keep: Vec<usize> = (all.n() - window..all.n()).collect();
    let tail_y = all.y[all.n() - window..].to_vec();
    let windowed = Dataset::new(all.name.clone(), all.x.gather_rows(&keep), tail_y);
    let fresh = MkaGp::fit(&windowed, &RbfKernel::new(0.8), SIGMA2, &c).unwrap();
    let xt = test_grid(older.dim());
    let po = obs.predict(&xt);
    let pf = fresh.predict(&xt);
    assert_bits_equal(&po.mean, &pf.mean, "mean");
    assert_bits_equal(&po.var, &pf.var, "var");
}

/// Contract 3: the full streaming pipeline is bit-deterministic across
/// thread counts — fit, observe (incremental path), predict and the
/// reported stage accounting all agree at 1, 2 and 4 threads.
#[test]
fn observe_pipeline_bit_deterministic_across_threads() {
    let _g = lock();
    let data = synth("oe-det", 160, 2, 17);
    let run = |threads: usize| {
        mka_gp::par::set_threads(threads);
        let (older, xb, yb) = split_tail(&data, 12);
        // Fixed task split (n_threads 2) executed on global pools of
        // different sizes — the same recipe as the sharded suite.
        let c = cfg(2);
        let base = MkaGp::fit(&older, &RbfKernel::new(0.8), SIGMA2, &c).unwrap();
        let (obs, report) = base.observed(&xb, &yb, &ObservePolicy::default()).unwrap();
        let p = obs.predict(&test_grid(older.dim()));
        let bits: Vec<u64> = p.mean.iter().chain(p.var.iter()).map(|v| v.to_bits()).collect();
        let stats = report.stats.map(|s| (s.stages_rebuilt, s.stages_reused, s.blocks_touched));
        (report.path, stats, bits, obs.log_marginal().unwrap().to_bits())
    };
    let serial = run(1);
    let two = run(2);
    let four = run(4);
    assert_eq!(serial, two, "2-thread observe diverged from serial");
    assert_eq!(serial, four, "4-thread observe diverged from serial");
    assert_eq!(serial.0, ObservePath::Incremental);
    mka_gp::par::set_threads(1);
}

/// Streaming batches accumulate: repeated observes keep tracking a
/// fresh fit on everything seen so far, batch after batch.
#[test]
fn repeated_observes_accumulate() {
    let _g = lock();
    let data = synth("oe-seq", 152, 2, 21);
    let (older, xb, yb) = split_tail(&data, 24);
    let c = cfg(1);
    let mut model = MkaGp::fit(&older, &RbfKernel::new(0.8), SIGMA2, &c).unwrap();
    let mut seen = older.clone();
    // three batches of 8, streamed one at a time
    for chunk in 0..3 {
        let idx: Vec<usize> = (chunk * 8..(chunk + 1) * 8).collect();
        let xc = xb.gather_rows(&idx);
        let yc: Vec<f64> = idx.iter().map(|&i| yb[i]).collect();
        let (next, report) = model.observed(&xc, &yc, &ObservePolicy::default()).unwrap();
        assert_eq!(report.appended, 8);
        seen = concat(&seen, &xc, &yc);
        assert_eq!(report.n_total, seen.n());
        model = next;
    }
    let fresh = MkaGp::fit(&seen, &RbfKernel::new(0.8), SIGMA2, &c).unwrap();
    let xt = test_grid(older.dim());
    let pm = model.predict(&xt);
    let pf = fresh.predict(&xt);
    assert_rel_close(&pm.mean, &pf.mean, 1e-9, "mean");
    assert_rel_close(&pm.var, &pf.var, 1e-9, "var");
}
