//! Property-based tests (hand-rolled generators — no proptest offline):
//! every property is checked across many random seeds / shapes, with the
//! failing seed printed for reproduction.

use mka_gp::compress::CompressorKind;
use mka_gp::kernels::{Kernel, LaplaceKernel, Matern32Kernel, RbfKernel};
use mka_gp::la::{gemv, Mat, SymEig};
use mka_gp::mka::{factorize, MkaConfig};
use mka_gp::util::{Json, Rng};

/// Random kernel matrix + points: varied n, d, lengthscale, kernel family.
fn random_kernel(seed: u64) -> (Mat, Mat, f64) {
    let mut rng = Rng::new(seed);
    let n = 40 + rng.below(120); // 40..160
    let d = 1 + rng.below(5);
    let ell = rng.uniform_in(0.3, 2.5);
    let sigma2 = rng.uniform_in(0.02, 0.4);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * rng.uniform_in(0.5, 2.0));
    let kern: Box<dyn Kernel> = match rng.below(3) {
        0 => Box::new(RbfKernel::new(ell)),
        1 => Box::new(LaplaceKernel::new(ell)),
        _ => Box::new(Matern32Kernel::new(ell)),
    };
    let mut k = kern.gram_sym(&x);
    k.add_diag(sigma2);
    (k, x, sigma2)
}

fn random_config(seed: u64, n: usize) -> MkaConfig {
    let mut rng = Rng::new(seed ^ 0xc0ffee);
    MkaConfig {
        d_core: 8 + rng.below(24),
        block_size: (16 + rng.below(48)).min(n).max(2),
        gamma: rng.uniform_in(0.35, 0.7),
        compressor: match rng.below(3) {
            0 => CompressorKind::Mmf,
            1 => CompressorKind::Spca,
            _ => CompressorKind::Evd,
        },
        seed,
        n_threads: 1 + rng.below(3),
        ..MkaConfig::default()
    }
}

const TRIALS: u64 = 12;

#[test]
fn prop_factor_is_valid_and_spsd() {
    for seed in 0..TRIALS {
        let (k, x, _) = random_kernel(seed);
        let cfg = random_config(seed, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(f.check_valid(), "seed {seed}: invalid factor");
        // Proposition 1: spsd preservation.
        assert!(f.min_eig() > 0.0, "seed {seed}: min eig {}", f.min_eig());
    }
}

#[test]
fn prop_matvec_is_symmetric_operator() {
    for seed in 0..TRIALS {
        let (k, x, _) = random_kernel(seed + 100);
        let cfg = random_config(seed + 100, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        let mut rng = Rng::new(seed + 999);
        let a = rng.normal_vec(k.rows);
        let b = rng.normal_vec(k.rows);
        let ka = f.matvec(&a);
        let kb = f.matvec(&b);
        let lhs: f64 = ka.iter().zip(&b).map(|(p, q)| p * q).sum();
        let rhs: f64 = a.iter().zip(&kb).map(|(p, q)| p * q).sum();
        assert!(
            (lhs - rhs).abs() <= 1e-8 * lhs.abs().max(1.0),
            "seed {seed}: ⟨Ka,b⟩={lhs} vs ⟨a,Kb⟩={rhs}"
        );
    }
}

#[test]
fn prop_solve_inverts_matvec() {
    for seed in 0..TRIALS {
        let (k, x, _) = random_kernel(seed + 200);
        let cfg = random_config(seed + 200, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        let mut rng = Rng::new(seed);
        let z = rng.normal_vec(k.rows);
        let b = f.matvec(&z);
        let back = f.solve(&b).unwrap();
        let err = back
            .iter()
            .zip(&z)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 1e-5, "seed {seed}: roundtrip err {err}");
    }
}

#[test]
fn prop_spectrum_matches_dense_evd() {
    for seed in 0..6 {
        let (k, x, _) = random_kernel(seed + 300);
        let cfg = random_config(seed + 300, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        let dense = f.to_dense();
        let e = SymEig::new(&dense);
        let s = f.spectrum();
        assert_eq!(s.len(), e.values.len());
        for (a, b) in s.iter().zip(&e.values) {
            assert!(
                (a - b).abs() < 1e-7 * b.abs().max(1.0),
                "seed {seed}: spectrum {a} vs dense {b}"
            );
        }
    }
}

#[test]
fn prop_logdet_and_det_consistent() {
    for seed in 0..6 {
        let (k, x, _) = random_kernel(seed + 400);
        let cfg = random_config(seed + 400, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        let ld = f.logdet().unwrap();
        let spectrum_ld: f64 = f.spectrum().iter().map(|v| v.abs().ln()).sum();
        assert!((ld - spectrum_ld).abs() < 1e-7 * ld.abs().max(1.0), "seed {seed}");
    }
}

#[test]
fn prop_matrix_functions_compose() {
    for seed in 0..6 {
        let (k, x, _) = random_kernel(seed + 500);
        let cfg = random_config(seed + 500, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        let mut rng = Rng::new(seed);
        let z = rng.normal_vec(k.rows);
        // K^(1/3) applied three times = K z
        let third = f.pow_apply(1.0 / 3.0, &z);
        let third2 = f.pow_apply(1.0 / 3.0, &third);
        let third3 = f.pow_apply(1.0 / 3.0, &third2);
        let direct = f.matvec(&z);
        for i in 0..k.rows {
            assert!(
                (third3[i] - direct[i]).abs() < 1e-6 * direct[i].abs().max(1.0),
                "seed {seed} i={i}"
            );
        }
        // exp(βK) exp(−βK) z = z
        let e1 = f.exp_apply(0.05, &z);
        let e2 = f.exp_apply(-0.05, &e1);
        for i in 0..k.rows {
            assert!((e2[i] - z[i]).abs() < 1e-7, "seed {seed} i={i}");
        }
    }
}

#[test]
fn prop_storage_bound_prop5() {
    for seed in 0..TRIALS {
        let (k, x, _) = random_kernel(seed + 600);
        // MMF only (the Prop-5 bound is MMF-specific), strict budget.
        let cfg = MkaConfig {
            compressor: CompressorKind::Mmf,
            d_core: 16,
            block_size: 32.min(k.rows).max(2),
            seed,
            ..MkaConfig::default()
        };
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        let s = f.n_stages();
        // default MMF performs 2 pre-sweeps per wavelet → (2·3·s + 1)n
        let per_wavelet = 2 * (1 + 2);
        let bound = (per_wavelet * s + 1) * f.n + f.d_core() * f.d_core();
        assert!(
            f.stored_reals() <= bound,
            "seed {seed}: {} > {bound}",
            f.stored_reals()
        );
    }
}

#[test]
fn prop_dense_reconstruction_error_bounded() {
    // The factorization is an approximation, but it must stay sane across
    // the whole random family (relative Frobenius error well below 1).
    for seed in 0..TRIALS {
        let (k, x, _) = random_kernel(seed + 700);
        let cfg = random_config(seed + 700, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        let rel = f.to_dense().sub(&k).frob_norm() / k.frob_norm();
        assert!(rel < 0.6, "seed {seed}: rel {rel}");
    }
}

#[test]
fn prop_matvec_matches_dense_application() {
    for seed in 0..6 {
        let (k, x, _) = random_kernel(seed + 800);
        let cfg = random_config(seed + 800, k.rows);
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        let dense = f.to_dense();
        let mut rng = Rng::new(seed * 31 + 1);
        let z = rng.normal_vec(k.rows);
        let fast = f.matvec(&z);
        let slow = gemv(&dense, &z);
        for i in 0..k.rows {
            assert!((fast[i] - slow[i]).abs() < 1e-9, "seed {seed} i={i}");
        }
    }
}

#[test]
fn prop_json_fuzz_roundtrip() {
    // Random JSON trees serialize → parse → identical.
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
            3 => {
                let len = rng.below(8);
                Json::Str((0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.below(5) {
                    o.set(&format!("k{i}"), random_json(rng, depth - 1));
                }
                o
            }
        }
    }
    let mut rng = Rng::new(4242);
    for _ in 0..300 {
        let v = random_json(&mut rng, 3);
        let back = Json::parse(&v.dump()).expect("parse back");
        assert_eq!(v, back);
        let back2 = Json::parse(&v.dump_pretty()).expect("pretty parse back");
        assert_eq!(v, back2);
    }
}
