//! Gradient correctness: every analytic `mll_grad` against central finite
//! differences of its own evidence value, the MKA Hutchinson probe
//! against its exact dense-trace path, bit-determinism of the probe
//! across thread counts, and planted anisotropic-lengthscale recovery via
//! ARD L-BFGS (which the isotropic parametrization cannot represent).

use mka_gp::data::dataset::Dataset;
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::experiments::methods::{mka_config_for, Method};
use mka_gp::gp::cv::ArdHyperParams;
use mka_gp::kernels::{ArdRbfKernel, Kernel};
use mka_gp::la::chol::Chol;
use mka_gp::la::dense::Mat;
use mka_gp::train::grad::{
    mll_grad, mll_grad_fitc, mll_grad_full, mll_grad_mka, mll_grad_pitc, mll_grad_sor, MllGrad,
    TraceMode,
};
use mka_gp::train::{maximize_mll, maximize_mll_lbfgs, OptimBudget, SearchBox};
use mka_gp::util::Rng;

fn data() -> Dataset {
    gp_dataset(&SynthSpec::named("fd", 80, 2), 3)
}

fn hp() -> ArdHyperParams {
    // Deliberately away from any optimum so every gradient component is
    // well off zero and the relative comparison is meaningful.
    ArdHyperParams { lengthscales: vec![0.9, 1.6], sigma2: 0.08 }
}

/// Shift parameter `p` (last = log σ²) of `hp` by `dir·h` in log space;
/// in tied mode the single length-scale parameter drives every dimension.
fn shifted(hp: &ArdHyperParams, tied: bool, p: usize, dir: f64, h: f64) -> ArdHyperParams {
    let mut s = hp.clone();
    let n_ell = if tied { 1 } else { s.lengthscales.len() };
    if p < n_ell {
        if tied {
            for l in &mut s.lengthscales {
                *l *= (dir * h).exp();
            }
        } else {
            s.lengthscales[p] *= (dir * h).exp();
        }
    } else {
        s.sigma2 *= (dir * h).exp();
    }
    s
}

/// Assert the analytic gradient matches central finite differences of the
/// evaluator's own value: |analytic − fd| ≤ 1e-5 · max(10, ‖fd‖∞)
/// per component (the paper-check tolerance, relative to the gradient
/// scale with a floor keeping FD roundoff out of the comparison).
fn assert_matches_fd(eval: &dyn Fn(&ArdHyperParams) -> MllGrad, hp: &ArdHyperParams, tied: bool) {
    let h = 1e-4;
    let g = eval(hp);
    let analytic = g.grad_vec();
    let fd: Vec<f64> = (0..analytic.len())
        .map(|p| {
            (eval(&shifted(hp, tied, p, 1.0, h)).mll - eval(&shifted(hp, tied, p, -1.0, h)).mll)
                / (2.0 * h)
        })
        .collect();
    let scale = fd.iter().fold(10.0f64, |m, v| m.max(v.abs()));
    for (p, (&a, &f)) in analytic.iter().zip(&fd).enumerate() {
        assert!(
            (a - f).abs() <= 1e-5 * scale,
            "tied={tied} param {p}: analytic {a} vs central-difference {f} (scale {scale})"
        );
    }
}

#[test]
fn full_gradient_matches_central_differences() {
    let d = data();
    for tied in [true, false] {
        assert_matches_fd(&|h| mll_grad_full(&d, h, tied).unwrap(), &hp(), tied);
    }
}

#[test]
fn sor_gradient_matches_central_differences() {
    let d = data();
    for tied in [true, false] {
        assert_matches_fd(&|h| mll_grad_sor(&d, h, tied, 10, 5).unwrap(), &hp(), tied);
    }
}

#[test]
fn fitc_gradient_matches_central_differences() {
    let d = data();
    for tied in [true, false] {
        assert_matches_fd(&|h| mll_grad_fitc(&d, h, tied, 10, 5).unwrap(), &hp(), tied);
    }
}

#[test]
fn pitc_gradient_matches_central_differences() {
    let d = data();
    for tied in [true, false] {
        assert_matches_fd(&|h| mll_grad_pitc(&d, h, tied, 10, 16, 5).unwrap(), &hp(), tied);
    }
}

/// With d_core ≥ n the factorization stores K + σ²I exactly, so the
/// MKA gradient with the exact dense-trace path must reproduce the Full
/// gradient — a non-stochastic end-to-end check of the cascade trace.
#[test]
fn mka_exact_trace_matches_full_gradient_without_compression() {
    let d = gp_dataset(&SynthSpec::named("fdm", 60, 2), 4);
    let hp = hp();
    let mut cfg = mka_config_for(16, d.n(), 5);
    cfg.d_core = d.n(); // no compression
    let mka = mll_grad_mka(&d, &hp, false, &cfg, TraceMode::Exact, 1).unwrap();
    let full = mll_grad_full(&d, &hp, false).unwrap();
    assert!(
        (mka.mll - full.mll).abs() < 1e-7 * full.mll.abs().max(1.0),
        "mll: mka {} vs full {}",
        mka.mll,
        full.mll
    );
    let (a, b) = (mka.grad_vec(), full.grad_vec());
    let scale = b.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for (p, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!((x - y).abs() <= 1e-6 * scale, "param {p}: mka {x} vs full {y}");
    }
}

/// Under real compression the fixed-seed Hutchinson probe batch must land
/// near the exact dense trace — the estimator's whole job.
#[test]
fn mka_probe_tracks_exact_trace_under_compression() {
    let d = gp_dataset(&SynthSpec::named("fdm", 60, 2), 4);
    let hp = hp();
    let cfg = mka_config_for(16, d.n(), 5);
    let exact = mll_grad_mka(&d, &hp, false, &cfg, TraceMode::Exact, 1).unwrap();
    let probe = mll_grad_mka(&d, &hp, false, &cfg, TraceMode::Probes(256), 99).unwrap();
    // The probe never touches the value or the (spectrum-exact) σ² term.
    assert_eq!(probe.mll.to_bits(), exact.mll.to_bits());
    assert_eq!(probe.d_log_sigma2.to_bits(), exact.d_log_sigma2.to_bits());
    let scale = exact.d_log_ell.iter().fold(10.0f64, |m, v| m.max(v.abs()));
    for (p, (a, e)) in probe.d_log_ell.iter().zip(&exact.d_log_ell).enumerate() {
        assert!(
            (a - e).abs() <= 0.5 * scale,
            "param {p}: probe {a} vs exact {e} (scale {scale})"
        );
    }
}

/// The probe rides one `solve_mat_par` cascade: bit-identical at any
/// thread count (the PR-2 determinism contract extended to training).
#[test]
fn mka_gradient_bit_deterministic_across_thread_counts() {
    let d = gp_dataset(&SynthSpec::named("fdm", 70, 2), 6);
    let hp = hp();
    let run = || mll_grad(Method::Mka, &d, &hp, false, 12, 7).unwrap();
    let a = run();
    mka_gp::par::set_threads(4);
    let b = run();
    mka_gp::par::set_threads(2);
    let c = run();
    mka_gp::par::set_threads(1);
    let e = run();
    for other in [&b, &c, &e] {
        assert_eq!(a.mll.to_bits(), other.mll.to_bits());
        assert_eq!(a.d_log_sigma2.to_bits(), other.d_log_sigma2.to_bits());
        for (x, y) in a.d_log_ell.iter().zip(&other.d_log_ell) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}

/// Plant strongly anisotropic per-dimension length scales, then show the
/// ARD L-BFGS path recovers them while the isotropic parametrization —
/// by construction — can only land in between, at measurably lower
/// evidence.
#[test]
fn ard_lbfgs_recovers_planted_anisotropic_lengthscales() {
    let (ell_short, ell_long) = (0.4, 4.0);
    let n = 110;
    let mut rng = Rng::new(17);
    let x = Mat::from_fn(n, 2, |_, _| rng.normal());
    let kern = ArdRbfKernel::new(vec![ell_short, ell_long]);
    let kf = kern.gram_sym(&x);
    let (chol, _) = Chol::new_jittered(&kf, 12).unwrap();
    // f ~ GP(0, K): f = L ε; observe y = f + 0.1·N(0,1).
    let eps = rng.normal_vec(n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = 0.0;
        for j in 0..=i {
            s += chol.l.at(i, j) * eps[j];
        }
        y[i] = s + 0.1 * rng.normal();
    }
    let d = Dataset::new("ard-planted", x, y);

    let sbox = SearchBox::for_dim(2);
    let budget = OptimBudget { max_evals: 90, n_starts: 3, tol: 1e-6 };
    let ard = maximize_mll_lbfgs(
        |h| mll_grad_full(&d, h, false).ok().map(|g| (g.mll, g.grad_vec())),
        2,
        true,
        &budget,
        &sbox,
    )
    .unwrap();
    let (l0, l1) = (ard.best.lengthscales[0], ard.best.lengthscales[1]);
    assert!(l0 < l1, "anisotropy direction lost: {:?}", ard.best);
    assert!(l1 / l0 >= 3.0, "planted ratio 10 collapsed to {}", l1 / l0);
    assert!(
        (l0.ln() - ell_short.ln()).abs() < 0.8,
        "short scale {l0} vs planted {ell_short}"
    );
    assert!(
        (l1.ln() - ell_long.ln()).abs() < 1.2,
        "long scale {l1} vs planted {ell_long}"
    );

    // The derivative-free isotropic path on the same surface: one tied ℓ
    // must compromise between the planted scales and pay in evidence.
    let iso = maximize_mll(
        |h| {
            mka_gp::train::log_marginal_likelihood(Method::Full, &d, h, 8, 7).ok()
        },
        2,
        &OptimBudget { max_evals: 90, n_starts: 3, tol: 1e-6 },
        &sbox,
    )
    .unwrap();
    assert!(
        ard.best_mll > iso.best_mll + 2.0,
        "ARD evidence {} should clearly beat isotropic {}",
        ard.best_mll,
        iso.best_mll
    );
    assert!(
        iso.best.lengthscale > 0.8 * l0 && iso.best.lengthscale < 1.2 * l1,
        "isotropic compromise {} not between ARD scales ({l0}, {l1})",
        iso.best.lengthscale
    );
}
