//! Steady-state allocation discipline of the serving hot path.
//!
//! After warm-up rounds have sized the per-worker arenas, the
//! cascade/gram pipeline must run entirely out of recycled scratch:
//! `arena::grows()` stays flat while `arena::checkouts()` keeps rising.
//! This is its own integration binary (own process) so no other test's
//! allocations pollute the global counters, and it pins a single-thread
//! pool so every checkout hits one thread-local arena deterministically.

use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::gp::mka_gp::MkaGp;
use mka_gp::gp::GpModel;
use mka_gp::kernels::{gram_sym_with, RbfKernel};
use mka_gp::la::Mat;
use mka_gp::mka::{factorize, MkaConfig};
use mka_gp::par::arena;
use mka_gp::util::Rng;

fn randm(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(r, c, |_, _| rng.normal())
}

#[test]
fn steady_state_cascade_and_gram_stop_growing_the_arena() {
    mka_gp::par::set_threads(1);
    let n = 260;
    let x = randm(n, 2, 9);
    let kern = RbfKernel::new(1.0);
    let cfg = MkaConfig { d_core: 24, block_size: 48, n_threads: 1, ..MkaConfig::default() };
    let k = gram_sym_with(&kern, &x, 1);
    let f = factorize(&k, Some(&x), &cfg).unwrap().shifted(0.1);
    arena::give_mat(k);

    let round = |cols: usize| {
        // One serving round: assemble a gram block and run a blocked
        // cascade solve, donating every buffer we own back to the arena.
        let g = gram_sym_with(&kern, &x, 1);
        arena::give_mat(g);
        let mut rhs = arena::take_mat_zeroed(n, cols);
        for j in 0..cols {
            rhs.set(j % n, j, 1.0);
        }
        let sol = f.solve_mat_par(&rhs, 1).unwrap();
        let probe = sol.at(0, 0);
        arena::give_mat(rhs);
        arena::give_mat(sol);
        probe
    };

    // Warm-up: size every buffer class the serving round checks out.
    let p0 = round(5);
    for _ in 0..3 {
        round(5);
    }

    let grows_before = arena::grows();
    let checkouts_before = arena::checkouts();
    for _ in 0..4 {
        // Recycled scratch must not leak state into results either.
        assert_eq!(round(5).to_bits(), p0.to_bits());
    }
    assert!(
        arena::checkouts() > checkouts_before,
        "serving rounds must go through the arena (checkouts stuck at {checkouts_before})"
    );
    assert_eq!(
        grows_before,
        arena::grows(),
        "steady-state serving must not grow the arena (grow_bytes now {})",
        arena::grow_bytes()
    );
}

#[test]
fn predict_is_bit_stable_over_recycled_scratch() {
    // Full predicts re-factorize the joint matrix (allocation is expected
    // there); what the arena must guarantee is that buffer recycling
    // never leaks stale state into results, and that the predict path
    // actually rides the arena.
    mka_gp::par::set_threads(1);
    let data = gp_dataset(&SynthSpec::named("arena", 300, 2), 17);
    let (tr, te) = data.split(0.9, 5);
    let cfg = MkaConfig { d_core: 24, block_size: 48, n_threads: 1, ..MkaConfig::default() };
    let model = MkaGp::fit(&tr, &RbfKernel::new(1.0), 0.1, &cfg).unwrap();

    let c0 = arena::checkouts();
    let first = model.predict(&te.x);
    assert!(arena::checkouts() > c0, "predict must check scratch out of the arena");
    for _ in 0..2 {
        let p = model.predict(&te.x);
        for i in 0..te.n() {
            assert_eq!(p.mean[i].to_bits(), first.mean[i].to_bits());
            assert_eq!(p.var[i].to_bits(), first.var[i].to_bits());
        }
    }
}
