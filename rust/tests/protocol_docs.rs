//! Keeps `docs/PROTOCOL.md` honest: every fenced example tagged
//! `json request` is parsed and routed through a live [`Router`] in
//! document order (examples share state, exactly like a client session),
//! and must come back `"ok": true`; blocks tagged `json request-error`
//! must come back `"ok": false`. Untagged/`json response` blocks are
//! illustrative and skipped — but still must parse as JSON.

use mka_gp::coordinator::{Router, ServiceConfig};
use mka_gp::util::Json;

const DOC: &str = include_str!("../../docs/PROTOCOL.md");

#[derive(Debug, PartialEq, Eq, Clone, Copy)]
enum BlockKind {
    Request,
    RequestError,
    Other,
}

/// Extract every ```json fenced block with its tag.
fn json_blocks(doc: &str) -> Vec<(BlockKind, String)> {
    let mut blocks = Vec::new();
    let mut current: Option<(BlockKind, Vec<&str>)> = None;
    for line in doc.lines() {
        let trimmed = line.trim_end();
        match &mut current {
            None => {
                if let Some(info) = trimmed.strip_prefix("```") {
                    let info = info.trim();
                    if info.starts_with("json") {
                        let kind = match info {
                            "json request" => BlockKind::Request,
                            "json request-error" => BlockKind::RequestError,
                            _ => BlockKind::Other,
                        };
                        current = Some((kind, Vec::new()));
                    } else if !info.is_empty() {
                        // a non-json fence: skip until it closes
                        current = Some((BlockKind::Other, Vec::new()));
                    }
                }
            }
            Some((kind, lines)) => {
                if trimmed == "```" {
                    blocks.push((*kind, lines.join("\n")));
                    current = None;
                } else {
                    lines.push(line);
                }
            }
        }
    }
    assert!(current.is_none(), "unterminated fenced block in PROTOCOL.md");
    blocks
}

#[test]
fn every_documented_example_routes_as_documented() {
    let blocks = json_blocks(DOC);
    let requests: Vec<&(BlockKind, String)> =
        blocks.iter().filter(|(k, _)| *k != BlockKind::Other).collect();
    assert!(
        requests.len() >= 12,
        "PROTOCOL.md should document a full session, found {} routable examples",
        requests.len()
    );

    let cfg = ServiceConfig { batch_window_ms: 0, n_workers: 2, ..Default::default() };
    let router = Router::new(cfg);
    for (i, (kind, text)) in requests.iter().enumerate() {
        let req = Json::parse(text)
            .unwrap_or_else(|e| panic!("example {i} is not valid JSON ({e:?}):\n{text}"));
        assert!(req.str_field("op").is_some(), "example {i} has no op:\n{text}");
        let resp = router.handle(&req);
        let ok = resp.get("ok") == Some(&Json::Bool(true));
        match kind {
            BlockKind::Request => assert!(
                ok,
                "documented request {i} failed to route:\n{text}\n→ {}",
                resp.dump()
            ),
            BlockKind::RequestError => assert!(
                !ok,
                "documented error example {i} unexpectedly succeeded:\n{text}\n→ {}",
                resp.dump()
            ),
            BlockKind::Other => unreachable!(),
        }
    }
}

#[test]
fn every_json_block_parses_even_the_illustrative_ones() {
    for (i, (_, text)) in json_blocks(DOC).iter().enumerate() {
        Json::parse(text).unwrap_or_else(|e| {
            panic!("PROTOCOL.md json block {i} does not parse ({e:?}):\n{text}")
        });
    }
}

#[test]
fn document_covers_every_router_op() {
    // The op list lives next to the router's dispatch match
    // (`router::OPS`); every op it advertises must be documented, so a
    // new op registered there without documentation fails here.
    for op in mka_gp::coordinator::router::OPS {
        assert!(
            DOC.contains(&format!("`{op}`")),
            "PROTOCOL.md does not document op {op:?}"
        );
    }
}
