//! Determinism and robustness properties of the shared compute plane.
//!
//! The pool's contract is that parallelism is *only* a wall-clock knob:
//! every parallel path (row-band GEMM/SYRK, tiled gram assembly, the
//! factorize rotation phases, block-parallel cascades, column-sharded
//! solves) must reproduce the serial result bit-for-bit at any thread
//! count. These tests pin that across thread counts 1/2/4, plus the pool
//! stress cases (nested submit, panic propagation, drop-while-busy).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::gp::mka_gp::MkaGp;
use mka_gp::gp::GpModel;
use mka_gp::kernels::gram::{rbf_tile_native, GramBuilder, TileEngine};
use mka_gp::kernels::{gram_sym_with, gram_with, Kernel, RbfKernel};
use mka_gp::la::blas::{
    gemm_mt, gemm_nt_mt, gemm_tn_mt, syrk_aat_mt, syrk_ata_mt,
};
use mka_gp::la::{Chol, Mat};
use mka_gp::mka::{factorize, MkaConfig};
use mka_gp::par::ThreadPool;
use mka_gp::util::Rng;

fn randm(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(r, c, |_, _| rng.normal())
}

#[test]
fn gemm_family_bit_identical_across_thread_counts() {
    // Sizes chosen to clear PAR_MIN_FLOPS so the banding really engages.
    let a = randm(180, 150, 1);
    let b = randm(150, 160, 2);
    let a_sq = randm(170, 180, 3);
    let serial = (
        gemm_mt(&a, &b, 1),
        gemm_tn_mt(&a_sq, &randm(170, 150, 4), 1),
        gemm_nt_mt(&a, &randm(190, 150, 5), 1),
        syrk_ata_mt(&a_sq, 1),
        syrk_aat_mt(&a_sq, 1),
    );
    for t in [2, 4] {
        assert_eq!(serial.0.data, gemm_mt(&a, &b, t).data, "gemm t={t}");
        assert_eq!(
            serial.1.data,
            gemm_tn_mt(&a_sq, &randm(170, 150, 4), t).data,
            "gemm_tn t={t}"
        );
        assert_eq!(
            serial.2.data,
            gemm_nt_mt(&a, &randm(190, 150, 5), t).data,
            "gemm_nt t={t}"
        );
        assert_eq!(serial.3.data, syrk_ata_mt(&a_sq, t).data, "syrk_ata t={t}");
        assert_eq!(serial.4.data, syrk_aat_mt(&a_sq, t).data, "syrk_aat t={t}");
    }
}

#[test]
fn gemm_family_bit_identical_across_simd_levels_and_threads() {
    // The dispatch tier is the second wall-clock-only knob next to the
    // thread count: every available level, crossed with every thread
    // count, must reproduce the scalar serial bits exactly (j-lane
    // vectorization keeps one serial fma chain per output element).
    use mka_gp::la::blas::{
        available_levels, gemm_acc_level, gemm_nt_level, gemm_tn_level, syrk_aat_level,
        syrk_ata_level, SimdLevel,
    };
    let a = randm(180, 150, 61);
    let b = randm(150, 160, 62);
    let a_sq = randm(170, 180, 63);
    let mut c_base = Mat::zeros(180, 160);
    gemm_acc_level(SimdLevel::Scalar, 1.0, &a, &b, &mut c_base);
    let tn = gemm_tn_level(SimdLevel::Scalar, &a_sq, &a_sq);
    let nt = gemm_nt_level(SimdLevel::Scalar, &a, &a);
    let ata = syrk_ata_level(SimdLevel::Scalar, &a_sq);
    let aat = syrk_aat_level(SimdLevel::Scalar, &a_sq);
    for &level in &available_levels() {
        let mut c = Mat::zeros(180, 160);
        gemm_acc_level(level, 1.0, &a, &b, &mut c);
        assert_eq!(c_base.data, c.data, "gemm_acc {level:?}");
        assert_eq!(tn.data, gemm_tn_level(level, &a_sq, &a_sq).data, "tn {level:?}");
        assert_eq!(nt.data, gemm_nt_level(level, &a, &a).data, "nt {level:?}");
        assert_eq!(ata.data, syrk_ata_level(level, &a_sq).data, "ata {level:?}");
        assert_eq!(aat.data, syrk_aat_level(level, &a_sq).data, "aat {level:?}");
    }
    // Threaded entry points dispatch at the ambient level; their bits must
    // sit in the same equivalence class.
    for t in [1, 2, 4] {
        assert_eq!(c_base.data, gemm_mt(&a, &b, t).data, "gemm level x t={t}");
        assert_eq!(ata.data, syrk_ata_mt(&a_sq, t).data, "ata level x t={t}");
    }
}

#[test]
fn gram_assembly_bit_identical_across_thread_counts() {
    let x = randm(200, 3, 6);
    let y = randm(170, 3, 7);
    let kern = RbfKernel::with_signal(0.8, 1.4);
    let sym1 = gram_sym_with(&kern, &x, 1);
    let rect1 = gram_with(&kern, &x, &y, 1);
    assert_eq!(sym1.asymmetry(), 0.0);
    for t in [2, 4] {
        assert_eq!(sym1.data, gram_sym_with(&kern, &x, t).data, "gram_sym t={t}");
        assert_eq!(rect1.data, gram_with(&kern, &x, &y, t).data, "gram t={t}");
    }
}

struct NativeTileEngine {
    tile: usize,
}

impl TileEngine for NativeTileEngine {
    fn tile(&self) -> usize {
        self.tile
    }
    fn max_dim(&self) -> usize {
        64
    }
    fn rbf_tile(&self, xb: &Mat, yb: &Mat, l: f64, sf: f64) -> Mat {
        rbf_tile_native(xb, yb, l, sf)
    }
}

#[test]
fn tiled_engine_gram_bit_identical_across_thread_counts() {
    let x = randm(150, 4, 8);
    let y = randm(130, 4, 9);
    let build = |threads: usize| {
        let eng: Arc<dyn TileEngine> = Arc::new(NativeTileEngine { tile: 16 });
        GramBuilder::rbf(0.9, 1.2, Some(eng)).with_threads(threads)
    };
    let sym1 = build(1).build_sym(&x);
    let rect1 = build(1).build(&x, &y);
    for t in [2, 4] {
        assert_eq!(sym1.data, build(t).build_sym(&x).data, "build_sym t={t}");
        assert_eq!(rect1.data, build(t).build(&x, &y).data, "build t={t}");
    }
}

fn kernel_matrix(n: usize, seed: u64) -> (Mat, Mat) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, 3, |_, _| rng.normal());
    let mut k = RbfKernel::new(1.0).gram_sym(&x);
    k.add_diag(0.1);
    (k, x)
}

#[test]
fn factorize_bit_identical_across_thread_counts() {
    // n >= 512 so the parallel rotation phases actually engage.
    let (k, x) = kernel_matrix(600, 10);
    let cfg = |t: usize| MkaConfig {
        d_core: 24,
        block_size: 64,
        n_threads: t,
        ..MkaConfig::default()
    };
    let f1 = factorize(&k, Some(&x), &cfg(1)).unwrap();
    let d1 = f1.to_dense();
    for t in [2, 4] {
        let ft = factorize(&k, Some(&x), &cfg(t)).unwrap();
        assert_eq!(f1.core.data, ft.core.data, "core t={t}");
        assert_eq!(f1.n_stages(), ft.n_stages(), "stages t={t}");
        for (s1, st) in f1.stages.iter().zip(ft.stages.iter()) {
            assert_eq!(s1.dvals, st.dvals, "dvals t={t}");
            assert_eq!(s1.core_global, st.core_global, "core idx t={t}");
        }
        // The cascade itself (block-parallel under t) reproduces serial.
        assert_eq!(d1.data, ft.to_dense().data, "to_dense t={t}");
    }
}

#[test]
fn solve_paths_bit_identical_across_thread_counts() {
    let (k, x) = kernel_matrix(600, 11);
    let f1 = factorize(
        &k,
        Some(&x),
        &MkaConfig { d_core: 24, block_size: 64, n_threads: 1, ..MkaConfig::default() },
    )
    .unwrap();
    let mut rng = Rng::new(12);
    let wide = Mat::from_fn(600, 40, |_, _| rng.normal());
    let narrow = Mat::from_fn(600, 3, |_, _| rng.normal());
    let wide1 = f1.solve_mat_par(&wide, 1).unwrap();
    let narrow1 = f1.solve_mat_par(&narrow, 1).unwrap();
    let mm1 = f1.matmat_par(&wide, 1);
    for t in [2, 4] {
        assert_eq!(wide1.data, f1.solve_mat_par(&wide, t).unwrap().data, "wide t={t}");
        assert_eq!(
            narrow1.data,
            f1.solve_mat_par(&narrow, t).unwrap().data,
            "narrow t={t}"
        );
        assert_eq!(mm1.data, f1.matmat_par(&wide, t).data, "matmat t={t}");
    }
}

#[test]
fn predict_bit_identical_across_thread_counts() {
    let data = gp_dataset(&SynthSpec::named("det", 360, 2), 13);
    let (tr, te) = data.split(0.88, 3);
    let kern = RbfKernel::new(1.0);
    let cfg = |t: usize| MkaConfig {
        d_core: 24,
        block_size: 48,
        n_threads: t,
        ..MkaConfig::default()
    };
    let p1 = MkaGp::fit(&tr, &kern, 0.1, &cfg(1)).unwrap().predict(&te.x);
    for t in [2, 4] {
        let pt = MkaGp::fit(&tr, &kern, 0.1, &cfg(t)).unwrap().predict(&te.x);
        for i in 0..te.n() {
            assert_eq!(p1.mean[i].to_bits(), pt.mean[i].to_bits(), "mean[{i}] t={t}");
            assert_eq!(p1.var[i].to_bits(), pt.var[i].to_bits(), "var[{i}] t={t}");
        }
    }
}

/// Tracing is strictly an observer: with a live request trace installed
/// (spans recording through fit, factorize, cascade and the pool
/// hand-off), predictions reproduce the untraced bits exactly at every
/// thread count — and the trace really did record the cascade.
#[test]
fn traced_predict_bit_identical_to_untraced() {
    let data = gp_dataset(&SynthSpec::named("obs-det", 360, 2), 13);
    let (tr, te) = data.split(0.88, 3);
    let kern = RbfKernel::new(1.0);
    let cfg = |t: usize| MkaConfig {
        d_core: 24,
        block_size: 48,
        n_threads: t,
        ..MkaConfig::default()
    };
    for t in [1, 2, 4] {
        let base = MkaGp::fit(&tr, &kern, 0.1, &cfg(t)).unwrap().predict(&te.x);
        let guard = mka_gp::obs::start_request("op.predict");
        let traced = MkaGp::fit(&tr, &kern, 0.1, &cfg(t)).unwrap().predict(&te.x);
        let trace = guard.finish();
        assert!(
            trace.spans.iter().any(|s| s.name.starts_with("gp.predict")),
            "t={t}: trace recorded no gp.predict span"
        );
        for i in 0..te.n() {
            assert_eq!(base.mean[i].to_bits(), traced.mean[i].to_bits(), "mean[{i}] t={t}");
            assert_eq!(base.var[i].to_bits(), traced.var[i].to_bits(), "var[{i}] t={t}");
        }
    }
}

/// Cached-factor evidence training is bit-identical at any pool size:
/// the per-run `FactorCache` stores deterministic σ²-independent halves,
/// so the hit/miss interleaving of concurrent Nelder–Mead starts cannot
/// leak into the selected hyperparameters or the trace.
#[test]
fn cached_mll_training_bit_identical_across_thread_counts() {
    use mka_gp::experiments::methods::Method;
    use mka_gp::train::{select_hyperparams, ModelSelection, OptimBudget};
    let data = gp_dataset(&SynthSpec::named("cache-det", 90, 2), 13);
    let sel =
        ModelSelection::Mll { budget: OptimBudget { max_evals: 18, n_starts: 3, tol: 1e-6 } };
    // NOTE: the *miss count* is intentionally absent from the tuple —
    // two starts racing on one key may both build (identical entries),
    // so build counts are timing-dependent even though every value is
    // bit-deterministic.
    let run = || {
        let r = select_hyperparams(Method::Mka, &data, &sel, 10, 5).unwrap();
        (
            r.best.lengthscale.to_bits(),
            r.best.sigma2.to_bits(),
            r.best_mll.unwrap().to_bits(),
            r.evals,
            r.trace.len(),
        )
    };
    let a = run();
    mka_gp::par::set_threads(4);
    let b = run();
    mka_gp::par::set_threads(2);
    let c = run();
    mka_gp::par::set_threads(1);
    let d = run();
    for (i, other) in [&b, &c, &d].into_iter().enumerate() {
        assert_eq!(&a, other, "thread-count run {i} diverged");
    }
}

#[test]
fn blocked_chol_solve_matches_per_column() {
    let b = randm(60, 64, 14);
    let mut a = mka_gp::la::gemm_nt(&b, &b);
    a.add_diag(0.5);
    let chol = Chol::new(&a).unwrap();
    let rhs = randm(60, 9, 15);
    let blocked = chol.solve_mat(&rhs);
    // A · X ≈ B and agreement with the per-column solver.
    let ax = mka_gp::la::gemm(&a, &blocked);
    assert!(ax.sub(&rhs).max_abs() < 1e-8);
    for j in 0..rhs.cols {
        let col = chol.solve(&rhs.col(j));
        for i in 0..60 {
            assert!((blocked.at(i, j) - col[i]).abs() < 1e-9, "({i},{j})");
        }
    }
}

#[test]
fn pool_stress_nested_submit() {
    let pool = ThreadPool::new(3);
    let count = AtomicUsize::new(0);
    let outer: Vec<Box<dyn FnOnce() + Send + '_>> = (0..6)
        .map(|_| {
            let pool_ref = &pool;
            let c = &count;
            let b: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..10)
                    .map(|_| {
                        let b2: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                            c.fetch_add(1, Ordering::SeqCst);
                        });
                        b2
                    })
                    .collect();
                pool_ref.run_all(inner);
            });
            b
        })
        .collect();
    pool.run_all(outer);
    assert_eq!(count.load(Ordering::SeqCst), 60);
}

#[test]
fn pool_stress_panic_propagation() {
    let pool = ThreadPool::new(2);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let tasks: Vec<Box<dyn FnOnce() + Send + 'static>> = (0..5)
            .map(|i| {
                let b: Box<dyn FnOnce() + Send + 'static> = Box::new(move || {
                    if i == 3 {
                        panic!("deliberate failure in task {i}");
                    }
                });
                b
            })
            .collect();
        pool.run_all(tasks);
    }));
    assert!(result.is_err(), "batch panic must reach the submitter");
    // The pool survives and keeps executing.
    let done = AtomicUsize::new(0);
    let d = &done;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
        .map(|_| {
            let b: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                d.fetch_add(1, Ordering::SeqCst);
            });
            b
        })
        .collect();
    pool.run_all(tasks);
    assert_eq!(done.load(Ordering::SeqCst), 4);
}

#[test]
fn pool_stress_drop_while_busy() {
    let pool = ThreadPool::new(2);
    let count = Arc::new(AtomicUsize::new(0));
    for _ in 0..24 {
        let c = Arc::clone(&count);
        pool.spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(1));
            c.fetch_add(1, Ordering::SeqCst);
        });
    }
    // Dropping a busy pool must drain the queue, not hang or lose work.
    drop(pool);
    assert_eq!(count.load(Ordering::SeqCst), 24);
}
