//! Predict-path joint-factor cache, end to end: bitwise hit/cold
//! equivalence across thread counts, zero factorizations on warm repeat
//! test sets, retune keeping entries hot, observe invalidating exactly
//! the touched shard, and LRU eviction accounting that reconciles with
//! the served metrics.

mod common;

use std::sync::Mutex;

use common::*;
use mka_gp::gp::mka_gp::MkaGp;
use mka_gp::gp::GpModel;
use mka_gp::kernels::RbfKernel;
use mka_gp::util::Json;

/// Serializes the suite: these tests assert on process-global tallies
/// (`mka::factorize_count`, the cache counters) that concurrent test
/// threads in this binary would otherwise perturb.
static GLOBAL: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// A cache hit serves exactly the bits the cold path computed — at 1, 2
/// and 4 factorization threads — and the instance counters account for
/// every lookup.
#[test]
fn cache_hit_is_bitwise_identical_across_thread_counts() {
    let _g = guard();
    let tr = synth("pc-threads", 140, 2, 7);
    let te = synth("pc-threads-test", 9, 2, 8);
    for threads in [1usize, 2, 4] {
        let cfg = small_cfg(threads);
        let model = MkaGp::fit(&tr, &RbfKernel::new(0.9), SIGMA2, &cfg).unwrap();
        let cold = model.predict(&te.x);
        for _ in 0..2 {
            let hot = model.predict(&te.x);
            assert!(bits_eq(&cold.mean, &hot.mean), "mean drifted at {threads} threads");
            assert!(bits_eq(&cold.var, &hot.var), "var drifted at {threads} threads");
        }
        assert_eq!(model.predict_cache().misses(), 1, "{threads} threads");
        assert_eq!(model.predict_cache().hits(), 2, "{threads} threads");
    }
}

/// Repeat-test-set serving through the protocol: after the first
/// (cold) predict, identical requests add zero factorizations and
/// answer with identical JSON.
#[test]
fn repeat_predicts_add_zero_factorizations() {
    let _g = guard();
    let r = test_router();
    let data = synth("pc-flat", 120, 2, 3);
    assert_ok(&r.handle(&fit_json("pf", "mka", &data, 16)));
    let rows: Vec<&[f64]> = vec![&[0.1, -0.2], &[0.5, 0.4], &[-0.3, 0.0]];
    let first = r.handle(&predict_json("pf", &rows));
    assert_ok(&first);
    let before = mka_gp::mka::factorize_count();
    for _ in 0..5 {
        let again = r.handle(&predict_json("pf", &rows));
        assert_ok(&again);
        assert_eq!(again.get("mean"), first.get("mean"));
        assert_eq!(again.get("var"), first.get("var"));
    }
    assert_eq!(mka_gp::mka::factorize_count(), before, "warm predicts must not factorize");
}

/// A σ²-only retune republishes with the cache still hot: the first
/// predict after `{"op":"retune"}` is a hit (no factorization), visible
/// through the diagnose section.
#[test]
fn retune_keeps_cache_entries_hot() {
    let _g = guard();
    let r = test_router();
    let data = synth("pc-retune", 110, 2, 5);
    assert_ok(&r.handle(&fit_json("pr", "mka", &data, 16)));
    let rows: Vec<&[f64]> = vec![&[0.2, 0.1], &[-0.4, 0.3]];
    assert_ok(&r.handle(&predict_json("pr", &rows)));
    let retune = Json::obj()
        .with("op", Json::Str("retune".into()))
        .with("model", Json::Str("pr".into()))
        .with("sigma2", Json::Num(0.23));
    assert_ok(&r.handle(&retune));
    let before = mka_gp::mka::factorize_count();
    assert_ok(&r.handle(&predict_json("pr", &rows)));
    assert_eq!(
        mka_gp::mka::factorize_count(),
        before,
        "retuned model must serve from the shared cache"
    );
    let d = r.handle(&Json::parse(r#"{"op":"diagnose","model":"pr"}"#).unwrap());
    assert_ok(&d);
    let pc = d.get("diagnose").unwrap().get("predict_cache").expect("predict_cache section");
    assert_eq!(pc.num_field("entries"), Some(1.0));
    assert!(pc.num_field("hits").unwrap() >= 1.0, "{pc:?}");
}

/// Observe on a sharded fleet invalidates exactly the touched shard's
/// cache entries; untouched shards keep theirs (Arc-shared through the
/// carry-over), all read per shard from the diagnose tree.
#[test]
fn observe_invalidates_exactly_the_touched_shard() {
    let _g = guard();
    let r = test_router();
    let data = synth("pc-shard", 150, 2, 11);
    assert_ok(&r.handle(&fit_json("ps", "mka", &data, 16).with("shards", Json::Num(3.0))));
    // Warm the routed shards with a spread of training rows.
    let rows: Vec<&[f64]> = (0..12).map(|i| data.x.row(i)).collect();
    assert_ok(&r.handle(&predict_json("ps", &rows)));
    let per_shard = |d: &Json| -> Vec<(usize, f64)> {
        d.get("diagnose")
            .unwrap()
            .get("shards")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| {
                (
                    s.num_field("shard").unwrap() as usize,
                    s.get("model")
                        .unwrap()
                        .get("predict_cache")
                        .expect("per-shard predict_cache")
                        .num_field("entries")
                        .unwrap(),
                )
            })
            .collect()
    };
    let diag = |r: &mka_gp::coordinator::Router| {
        r.handle(&Json::parse(r#"{"op":"diagnose","model":"ps"}"#).unwrap())
    };
    let warm = per_shard(&diag(&r));
    assert!(warm.iter().map(|(_, n)| n).sum::<f64>() >= 1.0, "warmup populated no shard cache");
    let out = r.handle(&observe_json("ps", &[&[0.05, -0.02]], &[0.3]));
    assert_ok(&out);
    let rep = out.get("observe").unwrap();
    assert_eq!(rep.num_field("shards_touched"), Some(1.0));
    let touched =
        rep.get("shards").unwrap().as_arr().unwrap()[0].num_field("shard").unwrap() as usize;
    let after = per_shard(&diag(&r));
    for ((s, warm_n), (s2, after_n)) in warm.iter().zip(&after) {
        assert_eq!(s, s2);
        if *s == touched {
            assert_eq!(*after_n, 0.0, "touched shard {s} must drop its entries");
        } else {
            assert_eq!(after_n, warm_n, "untouched shard {s} must keep its entries");
        }
    }
}

/// Overflowing the bounded cache evicts LRU entries whose count
/// reconciles exactly with the instance misses (`entries + evictions ==
/// misses`), and the service metrics surface the same traffic plus the
/// cached/cold/queue-wait latency histograms.
#[test]
fn lru_eviction_accounting_reconciles_with_metrics() {
    let _g = guard();
    let r = test_router();
    let data = synth("pc-lru", 100, 2, 13);
    assert_ok(&r.handle(&fit_json("pl", "mka", &data, 16)));
    // 10 distinct single-row test sets overflow the 8-entry default.
    for i in 0..10 {
        let row = [i as f64 * 0.07, -0.1];
        let rows: Vec<&[f64]> = vec![&row];
        assert_ok(&r.handle(&predict_json("pl", &rows)));
    }
    // Repeating the most recent test set is a hit.
    let row = [9.0 * 0.07, -0.1];
    let rows: Vec<&[f64]> = vec![&row];
    assert_ok(&r.handle(&predict_json("pl", &rows)));
    let d = r.handle(&Json::parse(r#"{"op":"diagnose","model":"pl"}"#).unwrap());
    assert_ok(&d);
    let pc = d.get("diagnose").unwrap().get("predict_cache").unwrap();
    assert_eq!(pc.num_field("capacity"), Some(8.0));
    assert_eq!(pc.num_field("entries"), Some(8.0));
    assert_eq!(pc.num_field("misses"), Some(10.0));
    assert_eq!(pc.num_field("evictions"), Some(2.0));
    assert_eq!(pc.num_field("hits"), Some(1.0));
    // Conservation: every miss either still resides or was evicted.
    assert_eq!(
        pc.num_field("entries").unwrap() + pc.num_field("evictions").unwrap(),
        pc.num_field("misses").unwrap()
    );
    // Service-level counters cover the instance tallies, and the batcher
    // split the served latencies by cache outcome.
    let m = r.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
    let compute = m.get("compute").unwrap();
    assert!(compute.num_field("predict_cache_misses").unwrap() >= 10.0);
    assert!(compute.num_field("predict_cache_hits").unwrap() >= 1.0);
    assert!(compute.num_field("predict_cache_evictions").unwrap() >= 2.0);
    let hists = m.get("histograms").unwrap();
    assert!(hists.get("op.predict_queue_secs").is_some(), "queue wait always recorded");
    assert!(hists.get("op.predict_cold_secs").is_some(), "misses land in the cold histogram");
    assert!(hists.get("op.predict_cached_secs").is_some(), "the hit lands in the cached histogram");
}
