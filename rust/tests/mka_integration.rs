//! Integration tests for the MKA pipeline: multi-stage factorizations on
//! realistic kernel matrices, checked against dense ground truth.

use mka_gp::cluster::ClusterMethod;
use mka_gp::compress::CompressorKind;
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::kernels::{Kernel, RbfKernel};
use mka_gp::la::{Chol, Mat, SymEig};
use mka_gp::mka::{factorize, MkaConfig};
use mka_gp::util::Rng;

fn kernel_system(n: usize, seed: u64) -> (Mat, Mat) {
    let data = gp_dataset(&SynthSpec::named("it", n, 3), seed);
    let mut k = RbfKernel::new(0.7).gram_sym(&data.x);
    k.add_diag(0.1);
    (k, data.x)
}

#[test]
fn deep_factorization_reaches_small_core() {
    let (k, x) = kernel_system(512, 1);
    let cfg = MkaConfig { d_core: 16, block_size: 64, ..MkaConfig::default() };
    let f = factorize(&k, Some(&x), &cfg).unwrap();
    assert!(f.n_stages() >= 4, "expected several stages, got {}", f.n_stages());
    assert!(f.d_core() <= 32);
    assert!(f.check_valid());
    // heavy compression: far fewer stored reals than dense
    assert!(f.stored_reals() * 10 < 512 * 512);
}

#[test]
fn solve_agrees_with_cholesky_within_approximation() {
    // K̃⁻¹b is the exact inverse of the approximate operator; compare it
    // with the true K⁻¹b — the angle between them must be small when the
    // approximation is good (gentle compression).
    let (k, x) = kernel_system(256, 2);
    let cfg = MkaConfig { d_core: 128, block_size: 128, gamma: 0.7, ..MkaConfig::default() };
    let f = factorize(&k, Some(&x), &cfg).unwrap();
    let chol = Chol::new(&k).unwrap();
    let mut rng = Rng::new(3);
    let b = rng.normal_vec(256);
    let exact = chol.solve(&b);
    let approx = f.solve(&b).unwrap();
    let dot: f64 = exact.iter().zip(&approx).map(|(a, b)| a * b).sum();
    let ne: f64 = exact.iter().map(|v| v * v).sum::<f64>().sqrt();
    let na: f64 = approx.iter().map(|v| v * v).sum::<f64>().sqrt();
    let cosine = dot / (ne * na);
    assert!(cosine > 0.9, "cosine(K̃⁻¹b, K⁻¹b) = {cosine}");
}

#[test]
fn error_decreases_with_d_core() {
    let (k, x) = kernel_system(300, 4);
    let rel = |d_core: usize| {
        let cfg = MkaConfig { d_core, block_size: 75, ..MkaConfig::default() };
        let f = factorize(&k, Some(&x), &cfg).unwrap();
        f.to_dense().sub(&k).frob_norm() / k.frob_norm()
    };
    let e8 = rel(8);
    let e64 = rel(64);
    let e150 = rel(150);
    assert!(e64 <= e8 + 0.02, "e64={e64} e8={e8}");
    assert!(e150 <= e64 + 0.02, "e150={e150} e64={e64}");
    assert!(e150 < 0.2, "e150={e150}");
}

#[test]
fn logdet_tracks_dense_logdet() {
    let (k, x) = kernel_system(200, 5);
    let exact = Chol::new(&k).unwrap().logdet();
    // Gentle compression tracks closely; aggressive compression stays in
    // the right ballpark (truncation replaces small-eigenvalue directions
    // with their larger diagonal values, biasing logdet upward).
    let cfg_gentle =
        MkaConfig { d_core: 128, block_size: 100, gamma: 0.7, ..MkaConfig::default() };
    let approx_gentle = factorize(&k, Some(&x), &cfg_gentle).unwrap().logdet().unwrap();
    assert!(
        (exact - approx_gentle).abs() < 0.15 * exact.abs(),
        "gentle: exact {exact} vs approx {approx_gentle}"
    );
    let cfg = MkaConfig { d_core: 64, block_size: 64, ..MkaConfig::default() };
    let approx = factorize(&k, Some(&x), &cfg).unwrap().logdet().unwrap();
    assert!(
        (exact - approx).abs() < 0.30 * exact.abs(),
        "aggressive: exact {exact} vs approx {approx}"
    );
}

#[test]
fn every_compressor_and_clustering_combination_works() {
    let (k, x) = kernel_system(150, 6);
    for comp in [CompressorKind::Mmf, CompressorKind::Spca, CompressorKind::Evd] {
        for cl in [ClusterMethod::Bisect, ClusterMethod::KMeans, ClusterMethod::Affinity] {
            let cfg = MkaConfig {
                d_core: 24,
                block_size: 50,
                compressor: comp,
                cluster_method: cl,
                ..MkaConfig::default()
            };
            let f = factorize(&k, Some(&x), &cfg)
                .unwrap_or_else(|e| panic!("{comp:?}/{cl:?}: {e}"));
            assert!(f.check_valid(), "{comp:?}/{cl:?}");
            let rel = f.to_dense().sub(&k).frob_norm() / k.frob_norm();
            assert!(rel < 0.5, "{comp:?}/{cl:?}: rel {rel}");
        }
    }
}

#[test]
fn multithreaded_matches_single_threaded() {
    let (k, x) = kernel_system(300, 7);
    let f1 = factorize(
        &k,
        Some(&x),
        &MkaConfig { d_core: 32, block_size: 60, n_threads: 1, ..MkaConfig::default() },
    )
    .unwrap();
    let f4 = factorize(
        &k,
        Some(&x),
        &MkaConfig { d_core: 32, block_size: 60, n_threads: 4, ..MkaConfig::default() },
    )
    .unwrap();
    // Thread count must not change the result (determinism).
    let d1 = f1.to_dense();
    let d4 = f4.to_dense();
    assert!(d1.sub(&d4).max_abs() < 1e-12);
}

#[test]
fn psd_preserved_even_with_tiny_noise() {
    // Near-singular kernel (tiny σ²): Proposition 1 must still hold.
    let data = gp_dataset(&SynthSpec::named("psd", 200, 2), 8);
    let mut k = RbfKernel::new(1.5).gram_sym(&data.x);
    k.add_diag(1e-8);
    let cfg = MkaConfig { d_core: 32, block_size: 50, ..MkaConfig::default() };
    let f = factorize(&k, Some(&data.x), &cfg).unwrap();
    assert!(f.min_eig() >= 0.0, "min eig {}", f.min_eig());
    let e = SymEig::new(&f.to_dense());
    assert!(e.values[0] > -1e-9);
}

#[test]
fn identity_matrix_is_exact() {
    // I is already core-diagonal: MKA must reproduce it exactly.
    let n = 100;
    let k = Mat::eye(n);
    let cfg = MkaConfig { d_core: 10, block_size: 25, ..MkaConfig::default() };
    let f = factorize(&k, None, &cfg).unwrap();
    assert!(f.to_dense().sub(&k).max_abs() < 1e-10);
    assert!((f.logdet().unwrap()).abs() < 1e-9);
}
