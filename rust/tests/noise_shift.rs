//! The noise-shift factor plane (acceptance tests): diagonal shifts
//! commute with the orthogonal stage cascade, so
//! `factorize(K + σ²I) ≡ factorize(K).shifted(σ²)` **exactly** — same
//! rotations (the default pivot rules score shift-invariant quantities),
//! spectrum moved by σ². These tests pin that equivalence to 1e-10
//! relative across solve / logdet / to_dense / evidence, the
//! zero-refactorization economics of σ²-only moves through the
//! [`FactorCache`], and the serving-plane `retune` path.

use mka_gp::coordinator::{Router, ServiceConfig};
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::experiments::methods::Method;
use mka_gp::gp::cv::HyperParams;
use mka_gp::gp::mka_gp::MkaGp;
use mka_gp::gp::GpModel;
use mka_gp::kernels::{Kernel, RbfKernel};
use mka_gp::la::dense::Mat;
use mka_gp::mka::{factorize, MkaConfig};
use mka_gp::train::mll::mll_from_factor;
use mka_gp::train::{log_marginal_likelihood_cached, FactorCache};
use mka_gp::util::{Json, Rng};

fn kernel_matrix(n: usize, d: usize, ell: f64, seed: u64) -> (Mat, Mat) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, d, |_, _| rng.normal());
    let k = RbfKernel::new(ell).gram_sym(&x); // noise-free
    (k, x)
}

fn cfg(d_core: usize, block: usize) -> MkaConfig {
    MkaConfig { d_core, block_size: block, ..MkaConfig::default() }
}

/// Acceptance: MKA solve/logdet/to_dense at (ℓ, σ²) via
/// `factorize(K).shifted(σ²)` match a fresh `factorize(K + σ²I)` within
/// 1e-10 relative — one noise-free factorization serves every σ².
#[test]
fn shift_view_equals_fresh_noisy_factorization() {
    let (k, x) = kernel_matrix(120, 3, 1.2, 1);
    let config = cfg(24, 40);
    let f0 = factorize(&k, Some(&x), &config).unwrap();
    let mut rng = Rng::new(2);
    let b = rng.normal_vec(120);
    let bmat = Mat::from_fn(120, 5, |_, _| rng.normal());

    for s2 in [1e-3, 0.1, 0.75] {
        let mut ks = k.clone();
        ks.add_diag(s2);
        let fresh = factorize(&ks, Some(&x), &config).unwrap();
        let view = f0.shifted(s2);

        // Dense reconstruction: identical rotations + shifted spectrum.
        let d_fresh = fresh.to_dense();
        let d_view = view.to_dense();
        let rel = d_fresh.sub(&d_view).max_abs() / d_fresh.max_abs();
        assert!(rel < 1e-10, "to_dense rel {rel} at σ²={s2}");

        // logdet.
        let (ld_f, ld_v) = (fresh.logdet().unwrap(), view.logdet().unwrap());
        assert!(
            (ld_f - ld_v).abs() < 1e-10 * ld_f.abs().max(1.0),
            "logdet {ld_f} vs {ld_v} at σ²={s2}"
        );

        // solve (vector + blocked).
        let (s_f, s_v) = (fresh.solve(&b).unwrap(), view.solve(&b).unwrap());
        let scale = s_f.iter().fold(0.0f64, |m, v| m.max(v.abs())).max(1.0);
        for i in 0..120 {
            assert!(
                (s_f[i] - s_v[i]).abs() < 1e-10 * scale,
                "solve[{i}] {} vs {} at σ²={s2}",
                s_f[i],
                s_v[i]
            );
        }
        let sm_f = fresh.solve_mat(&bmat).unwrap();
        let sm_v = view.solve_mat(&bmat).unwrap();
        let rel = sm_f.sub(&sm_v).max_abs() / sm_f.max_abs().max(1.0);
        assert!(rel < 1e-10, "solve_mat rel {rel} at σ²={s2}");

        // spectrum (Proposition 7's explicit eigenvalues).
        for (a, b) in fresh.spectrum().iter().zip(view.spectrum()) {
            assert!((a - b).abs() < 1e-10 * a.abs().max(1.0), "spectrum {a} vs {b}");
        }
    }
}

/// The evidence at (ℓ, σ²) through the shifted view matches the evidence
/// of a fresh noisy factorization to 1e-10 relative — the quantity the
/// training plane's cache serves.
#[test]
fn shifted_evidence_matches_fresh_factorization() {
    let data = gp_dataset(&SynthSpec::named("shift-mll", 110, 2), 3);
    let kern = RbfKernel::new(1.0);
    let config = cfg(20, 36);
    let k = kern.gram_sym(&data.x);
    let f0 = factorize(&k, Some(&data.x), &config).unwrap();
    for s2 in [0.01, 0.1, 0.4] {
        let mut ks = k.clone();
        ks.add_diag(s2);
        let fresh = factorize(&ks, Some(&data.x), &config).unwrap();
        let via_fresh = mll_from_factor(&fresh, &data.y).unwrap();
        let via_view = mll_from_factor(&f0.shifted(s2), &data.y).unwrap();
        assert!(
            (via_fresh - via_view).abs() < 1e-10 * via_fresh.abs().max(1.0),
            "σ²={s2}: fresh {via_fresh} vs shifted view {via_view}"
        );
    }
}

/// σ²-only hyperparameter moves at a fixed length scale cost exactly one
/// factorization, however many evaluations run — the per-lengthscale
/// cache counts its own builds, so this pin is immune to concurrent
/// tests touching the global counters.
#[test]
fn sigma_only_moves_factorize_once() {
    let data = gp_dataset(&SynthSpec::named("shift-cache", 100, 2), 4);
    let cache = FactorCache::new(4);
    let sigmas = [0.02, 0.05, 0.1, 0.2, 0.4, 0.8];
    let mut values = Vec::new();
    for &s2 in &sigmas {
        let hp = HyperParams { lengthscale: 1.1, sigma2: s2 };
        values.push(
            log_marginal_likelihood_cached(Method::Mka, &data, hp, 12, 3, &cache).unwrap(),
        );
    }
    assert_eq!(cache.misses(), 1, "one ℓ ⇒ one factorization");
    assert_eq!(cache.hits(), (sigmas.len() - 1) as u64);
    // sanity: different σ² genuinely produce different evidence values
    for w in values.windows(2) {
        assert!(w[0] != w[1], "evidence must move with σ²");
    }
    // and every cached value is bit-identical to an uncached evaluation
    for (&s2, &v) in sigmas.iter().zip(&values) {
        let hp = HyperParams { lengthscale: 1.1, sigma2: s2 };
        let plain =
            log_marginal_likelihood_cached(Method::Mka, &data, hp, 12, 3, &FactorCache::disabled())
                .unwrap();
        assert_eq!(plain.to_bits(), v.to_bits(), "σ²={s2}");
    }
}

/// End-to-end retune through the coordinator: the republished model must
/// serve exactly what a model fitted fresh at the new σ² serves.
#[test]
fn retune_op_equals_fresh_fit() {
    let cfg_srv = ServiceConfig { batch_window_ms: 0, n_workers: 2, ..Default::default() };
    let r = Router::new(cfg_srv);
    let data = gp_dataset(&SynthSpec::named("retune", 80, 2), 5);
    let n = data.n();
    let x: Vec<Json> = (0..n).map(|i| Json::from_f64_slice(data.x.row(i))).collect();
    let fit = |model: &str, sigma2: f64| {
        Json::obj()
            .with("op", Json::Str("fit".into()))
            .with("model", Json::Str(model.into()))
            .with("method", Json::Str("mka".into()))
            .with("x", Json::Arr(x.clone()))
            .with("y", Json::from_f64_slice(&data.y))
            .with(
                "params",
                Json::obj()
                    .with("lengthscale", Json::Num(1.0))
                    .with("sigma2", Json::Num(sigma2))
                    .with("k", Json::Num(10.0)),
            )
            .with("async", Json::Bool(false))
    };
    // Fit at σ² = 0.1, retune to 0.3; fit a reference model at 0.3.
    assert_eq!(r.handle(&fit("m", 0.1)).get("ok"), Some(&Json::Bool(true)));
    assert_eq!(r.handle(&fit("m-ref", 0.3)).get("ok"), Some(&Json::Bool(true)));
    let retune = Json::parse(r#"{"op":"retune","model":"m","sigma2":0.3}"#).unwrap();
    let out = r.handle(&retune);
    assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");

    let predict = |model: &str| {
        let req = Json::obj()
            .with("op", Json::Str("predict".into()))
            .with("model", Json::Str(model.into()))
            .with(
                "x",
                Json::Arr(vec![
                    Json::from_f64_slice(&[0.2, -0.1]),
                    Json::from_f64_slice(&[-0.4, 0.6]),
                ]),
            );
        let resp = r.handle(&req);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        (
            resp.get("mean").unwrap().f64_array().unwrap(),
            resp.get("var").unwrap().f64_array().unwrap(),
        )
    };
    let (mean_rt, var_rt) = predict("m");
    let (mean_ref, var_ref) = predict("m-ref");
    for i in 0..2 {
        assert!(
            (mean_rt[i] - mean_ref[i]).abs() < 1e-10,
            "mean[{i}]: retuned {} vs fresh {}",
            mean_rt[i],
            mean_ref[i]
        );
        assert!((var_rt[i] - var_ref[i]).abs() < 1e-10, "var[{i}]");
        assert!(var_rt[i] >= 0.3, "variance floor must follow the new σ²");
    }
}

/// Direct model-level equivalence with heavier compression, including the
/// `GpModel::with_noise` hook the retune op rides.
#[test]
fn set_noise_prediction_equals_refit_under_compression() {
    let data = gp_dataset(&SynthSpec::named("retune-c", 150, 3), 6);
    let (tr, te) = data.split(0.85, 7);
    let kern = RbfKernel::new(0.9);
    let config = cfg(12, 30);
    let mut model = MkaGp::fit(&tr, &kern, 0.08, &config).unwrap();
    model.set_noise(0.3).unwrap();
    let fresh = MkaGp::fit(&tr, &kern, 0.3, &config).unwrap();
    let pa = model.predict(&te.x);
    let pb = fresh.predict(&te.x);
    for i in 0..te.n() {
        assert!((pa.mean[i] - pb.mean[i]).abs() < 1e-10, "mean[{i}]");
        assert!((pa.var[i] - pb.var[i]).abs() < 1e-10, "var[{i}]");
        assert!(pa.var[i] >= 0.3, "σ² floor violated: {}", pa.var[i]);
    }
    let via_trait = model.with_noise(0.08).expect("retune back");
    let back = MkaGp::fit(&tr, &kern, 0.08, &config).unwrap();
    let pc = via_trait.predict(&te.x);
    let pd = back.predict(&te.x);
    for i in 0..te.n() {
        assert!((pc.mean[i] - pd.mean[i]).abs() < 1e-10);
        assert!((pc.var[i] - pd.var[i]).abs() < 1e-10);
    }
}
