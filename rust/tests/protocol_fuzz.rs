//! Seeded protocol fuzz over every router op family.
//!
//! Each of the router's advertised ops gets 1024 randomized requests
//! built by mutating a valid skeleton: dropped fields, wrong-typed
//! values, boundary numbers (±1e308, 5e-324, −0.0, 2⁵³), boundary-size
//! matrices (0×0 up to 64×3), mangled/truncated op names and junk
//! fields. The contracts under fuzz:
//!
//! - every response carries an `ok` bool — the router never panics;
//! - every failure is typed (non-empty `error` string);
//! - the `errors` counter moves by exactly the number of non-`busy`
//!   failures (`Busy` is shed load, not an error);
//! - no poisoned state: after the storm, a clean fit → predict round
//!   trip and the metrics plane still work.
//!
//! A second test drives the wire layer: skeleton bodies truncated at
//! every prefix and randomly byte-spliced must never panic the JSON
//! parser, and whatever still parses must get a typed answer.
//!
//! Generators follow the `properties.rs` idiom: hand-rolled, seeded
//! per family, with the family and iteration printed on failure so any
//! counterexample replays deterministically.

use std::panic::{catch_unwind, AssertUnwindSafe};

use mka_gp::coordinator::router::OPS;
use mka_gp::coordinator::Router;
use mka_gp::util::{Json, Rng};

mod common;
use common::{assert_ok, fit_json, observe_json, predict_json, synth, test_config};

const PER_FAMILY: usize = 1024;

/// Boundary numerics: signed zero, subnormal, max finite, 2⁵³.
const NUMS: &[f64] =
    &[0.0, -0.0, 1.0, -1.0, 0.5, -7.5, 1e-12, 1e12, 1e308, -1e308, 5e-324, 9007199254740992.0];

fn fuzz_router() -> Router {
    let mut cfg = test_config();
    // An accidentally-valid fuzzed `refresh` schedule must never fire
    // mid-test: push the interval floor out past the test's lifetime.
    cfg.refresh_min_interval_ms = 3_600_000;
    Router::new(cfg)
}

fn word(rng: &mut Rng) -> String {
    (0..rng.below(9)).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num(NUMS[rng.below(NUMS.len())]),
        3 => Json::Str(word(rng)),
        4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let mut o = Json::obj();
            for _ in 0..rng.below(5) {
                o.set(&word(rng), random_json(rng, depth - 1));
            }
            o
        }
    }
}

/// Protocol fields the mutator targets with wrong-typed values.
const FIELDS: &[&str] = &[
    "op",
    "model",
    "method",
    "x",
    "y",
    "params",
    "sigma2",
    "lengthscale",
    "k",
    "shards",
    "batch_window_ms",
    "async",
    "job_id",
    "selection",
    "budget",
    "ard",
    "every_ms",
    "window",
    "drift_threshold",
    "max_core_growth",
    "n",
    "level",
];

/// One valid request skeleton per advertised op (coverage pinned by
/// `fuzz_skeletons_cover_every_advertised_op`). Kept tiny so requests
/// that survive mutation intact stay cheap to actually execute.
fn skeletons() -> Vec<(&'static str, Json)> {
    let data = synth("fz", 8, 1, 7);
    let op = |name: &str| Json::obj().with("op", Json::Str(name.into()));
    let mut train = fit_json("fz-t", "mka", &data, 2);
    train.set("op", Json::Str("train".into()));
    train.set("selection", Json::Str("mll".into()));
    train.set(
        "budget",
        Json::obj().with("max_evals", Json::Num(2.0)).with("n_starts", Json::Num(1.0)),
    );
    vec![
        ("ping", op("ping")),
        ("fit", fit_json("fz", "mka", &data, 2).with("batch_window_ms", Json::Num(0.0))),
        ("train", train),
        ("job", op("job").with("job_id", Json::Num(1.0))),
        ("predict", predict_json("fz", &[&[0.25], &[0.75]])),
        (
            "retune",
            op("retune").with("model", Json::Str("fz".into())).with("sigma2", Json::Num(0.2)),
        ),
        ("models", op("models")),
        ("drop_model", op("drop_model").with("model", Json::Str("ghost".into()))),
        ("metrics", op("metrics")),
        ("config", op("config")),
        ("trace", op("trace")),
        ("logs", op("logs").with("n", Json::Num(4.0))),
        ("diagnose", op("diagnose").with("model", Json::Str("fz".into()))),
        ("observe", observe_json("fz", &[&[0.3]], &[0.1])),
        (
            "refresh",
            op("refresh").with("model", Json::Str("fz".into())).with("every_ms", Json::Num(0.0)),
        ),
    ]
}

/// Apply one random corruption to a request object.
fn mutate(req: &mut Json, rng: &mut Rng) {
    let Json::Obj(map) = req else { unreachable!("skeletons are objects") };
    match rng.below(6) {
        // drop a field — body truncated at the field level
        0 => {
            let keys: Vec<String> = map.keys().cloned().collect();
            if !keys.is_empty() {
                map.remove(&keys[rng.below(keys.len())]);
            }
        }
        // wrong-typed / garbage value on a known protocol field
        1 | 2 => {
            let f = FIELDS[rng.below(FIELDS.len())];
            map.insert(f.into(), random_json(rng, 2));
        }
        // boundary-size matrix / vector payloads (empty, ragged-prone)
        3 => {
            let rows = [0usize, 1, 2, 64][rng.below(4)];
            let cols = [0usize, 1, 3][rng.below(3)];
            let m = Json::Arr(
                (0..rows)
                    .map(|_| Json::Arr((0..cols).map(|_| Json::Num(rng.normal())).collect()))
                    .collect(),
            );
            map.insert(if rng.below(2) == 0 { "x" } else { "y" }.to_string(), m);
        }
        // mangle the op itself: random word, number, or truncated name
        4 => {
            let cur = match map.get("op") {
                Some(Json::Str(s)) => s.clone(),
                _ => String::new(),
            };
            let newop = match rng.below(3) {
                0 => Json::Str(word(rng)),
                1 => Json::Num(NUMS[rng.below(NUMS.len())]),
                _ => Json::Str(cur[..rng.below(cur.len() + 1)].to_string()),
            };
            map.insert("op".into(), newop);
        }
        // pile on junk fields the router must ignore or reject
        _ => {
            for _ in 0..1 + rng.below(3) {
                map.insert(word(rng), random_json(rng, 1));
            }
        }
    }
}

/// The fuzz families and the router's advertised op list must stay in
/// lockstep — adding an op without fuzz coverage fails here.
#[test]
fn fuzz_skeletons_cover_every_advertised_op() {
    let families: Vec<&str> = skeletons().iter().map(|(f, _)| *f).collect();
    assert_eq!(families, OPS.to_vec());
}

#[test]
fn fuzz_every_op_family_yields_typed_errors_and_no_poisoned_state() {
    let router = fuzz_router();
    // A live model gives the deep paths (predict, observe, retune,
    // diagnose) something to actually hit when a mutation leaves the
    // request valid.
    assert_ok(&router.handle(&fit_json("fz", "mka", &synth("fz", 8, 1, 7), 2)));
    let errors_before = router.metrics.counter("errors");
    let mut failures = 0u64;
    let mut busy = 0u64;
    for (fi, (family, skel)) in skeletons().into_iter().enumerate() {
        let mut rng = Rng::new(0xf022 + 7919 * fi as u64);
        for it in 0..PER_FAMILY {
            let mut req = skel.clone();
            for _ in 0..1 + rng.below(3) {
                mutate(&mut req, &mut rng);
            }
            let resp = catch_unwind(AssertUnwindSafe(|| router.handle(&req)))
                .unwrap_or_else(|_| panic!("{family}[{it}]: router panicked on {req:?}"));
            match resp.get("ok") {
                Some(Json::Bool(true)) => {}
                Some(Json::Bool(false)) => {
                    let msg = resp.str_field("error").unwrap_or("");
                    assert!(!msg.is_empty(), "{family}[{it}]: untyped failure for {req:?}");
                    if resp.get("busy") == Some(&Json::Bool(true)) {
                        // Busy responses have a fixed shape: a backoff
                        // hint and the queue depth they were shed at.
                        assert!(
                            resp.num_field("retry_after_ms").unwrap_or(0.0) >= 1.0,
                            "{family}[{it}]: busy without retry_after_ms: {resp:?}"
                        );
                        assert!(
                            resp.num_field("depth").is_some(),
                            "{family}[{it}]: busy without depth: {resp:?}"
                        );
                        busy += 1;
                    } else {
                        failures += 1;
                    }
                }
                other => panic!("{family}[{it}]: no ok field ({other:?}) for {req:?}"),
            }
        }
    }
    // The errors counter saw exactly the non-busy failures — nothing
    // double-counted, nothing swallowed, shed load excluded.
    assert_eq!(
        router.metrics.counter("errors") - errors_before,
        failures,
        "errors counter out of sync (busy responses: {busy})"
    );
    assert!(failures > 0, "fuzz produced no failures — the mutator is broken");

    // No poisoned state: a clean fit → predict round trip still works…
    let data = synth("post-fuzz", 64, 1, 11);
    assert_ok(&router.handle(&fit_json("pf", "mka", &data, 8)));
    let resp = router.handle(&predict_json("pf", &[&[0.2], &[0.8]]));
    assert_ok(&resp);
    let mean = resp.get("mean").unwrap().f64_array().unwrap();
    assert_eq!(mean.len(), 2);
    assert!(mean.iter().all(|m| m.is_finite()), "post-fuzz predict mean {mean:?}");
    // …and so do the streaming and introspection planes.
    assert_ok(&router.handle(&observe_json("pf", &[&[0.5]], &[0.0])));
    assert_ok(&router.handle(&Json::obj().with("op", Json::Str("metrics".into()))));
}

/// Wire-layer fuzz: truncated and byte-spliced request bodies must
/// never panic the parser, and any body that still parses must get a
/// typed response from the router.
#[test]
fn truncated_and_spliced_wire_bodies_never_panic() {
    let router = fuzz_router();
    let mut rng = Rng::new(0x7c0de);
    let mut still_parsed = 0usize;
    for (family, skel) in skeletons() {
        let dump = skel.dump();
        // every prefix of the body — the "connection died mid-write" shape
        for cut in 0..dump.len() {
            let piece = &dump[..cut];
            let parsed = catch_unwind(|| Json::parse(piece).ok())
                .unwrap_or_else(|_| panic!("{family}: parser panicked on prefix {cut}"));
            if let Some(j) = parsed {
                let r = router.handle(&j);
                assert!(r.get("ok").is_some(), "{family}: prefix {cut} got no ok field");
            }
        }
        // random single-byte splices — framing bytes into the middle
        for it in 0..64 {
            let mut bytes = dump.clone().into_bytes();
            let i = rng.below(bytes.len());
            bytes[i] = b"{}[],:\"0x"[rng.below(9)];
            let Ok(text) = String::from_utf8(bytes) else { continue };
            let parsed = catch_unwind(AssertUnwindSafe(|| Json::parse(&text).ok()))
                .unwrap_or_else(|_| panic!("{family}[{it}]: parser panicked on {text:?}"));
            if let Some(j) = parsed {
                still_parsed += 1;
                let r = router.handle(&j);
                assert!(r.get("ok").is_some(), "{family}[{it}]: spliced body got no ok field");
            }
        }
    }
    // Some splices must survive parsing, or the router half of this
    // test never executed.
    assert!(still_parsed > 0, "no spliced body parsed — splice generator too destructive");
}
