//! End-to-end exercises of the observability plane.
//!
//! Four properties, each pinned against the real serving stack:
//!
//! 1. **Tracing is a pure observer** — fit, predict and hyperparameter
//!    training produce bit-identical values with a live request trace
//!    installed (the thread-count analogue lives in
//!    `par_determinism.rs`).
//! 2. **Span trees reach stage depth** — a traced sharded predict
//!    records the full chain router op → fleet → pool job → shard
//!    expert → cascade stage, with parents intact across the pool.
//! 3. **Rings stay bounded** — traces and events never outgrow their
//!    configured capacities no matter how many are pushed.
//! 4. **The coordinator round-trips** — over a real TCP connection with
//!    a Chrome trace-event sink attached: traced vs untraced predicts
//!    agree exactly, `trace`/`logs`/`diagnose` answer, `diagnose` does
//!    not refactorize, and the sink file is viewer-loadable.

use mka_gp::cluster::ClusterMethod;
use mka_gp::coordinator::ServiceConfig;
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::experiments::methods::Method;
use mka_gp::gp::mka_gp::MkaGp;
use mka_gp::gp::sharded::ShardedGp;
use mka_gp::gp::GpModel;
use mka_gp::kernels::RbfKernel;
use mka_gp::mka::MkaConfig;
use mka_gp::obs;
use mka_gp::train::{select_hyperparams, ModelSelection, OptimBudget};
use mka_gp::util::Json;

mod common;
use common::{fit_json, small_cfg, synth, tcp_rig};

#[test]
fn tracing_changes_no_bits_in_fit_predict_train() {
    let data = gp_dataset(&SynthSpec::named("obs-bits", 200, 2), 21);
    let (tr, te) = data.split(0.85, 4);
    let kern = RbfKernel::new(1.0);
    let cfg = small_cfg(2);

    let base_model = MkaGp::fit(&tr, &kern, 0.1, &cfg).unwrap();
    let base_pred = base_model.predict(&te.x);
    let base_mll = base_model.log_marginal().unwrap();

    let guard = obs::start_request("op.fit+predict");
    let traced_model = MkaGp::fit(&tr, &kern, 0.1, &cfg).unwrap();
    let traced_pred = traced_model.predict(&te.x);
    let traced_mll = traced_model.log_marginal().unwrap();
    let trace = guard.finish();

    assert_eq!(base_mll.to_bits(), traced_mll.to_bits(), "log marginal moved under tracing");
    for i in 0..te.n() {
        assert_eq!(base_pred.mean[i].to_bits(), traced_pred.mean[i].to_bits(), "mean[{i}]");
        assert_eq!(base_pred.var[i].to_bits(), traced_pred.var[i].to_bits(), "var[{i}]");
    }
    // ... and the trace actually saw the work it observed.
    assert!(
        trace.spans.iter().any(|s| s.name.starts_with("gp.predict")),
        "no gp.predict span recorded"
    );
    assert!(
        trace.spans.iter().any(|s| s.name.starts_with("mka.factorize")),
        "no mka.factorize span recorded"
    );

    // Hyperparameter training: the evidence search (multi-start
    // Nelder-Mead over the cached factor plane) selects bit-identical
    // hyperparameters traced vs untraced.
    let small = gp_dataset(&SynthSpec::named("obs-train", 70, 2), 9);
    let sel =
        ModelSelection::Mll { budget: OptimBudget { max_evals: 10, n_starts: 2, tol: 1e-6 } };
    let plain = select_hyperparams(Method::Mka, &small, &sel, 10, 5).unwrap();
    let tguard = obs::start_request("op.train");
    let traced = select_hyperparams(Method::Mka, &small, &sel, 10, 5).unwrap();
    let ttrace = tguard.finish();
    assert_eq!(plain.best.lengthscale.to_bits(), traced.best.lengthscale.to_bits());
    assert_eq!(plain.best.sigma2.to_bits(), traced.best.sigma2.to_bits());
    assert_eq!(plain.best_mll.unwrap().to_bits(), traced.best_mll.unwrap().to_bits());
    assert_eq!(plain.evals, traced.evals);
    assert!(
        ttrace.spans.iter().any(|s| s.name.starts_with("train.select")),
        "no train.select span recorded"
    );
}

/// A traced sharded predict must record the whole chain
/// `op → sharded.predict → pool.job → shard k predict → gp.predict →
/// stage i fwd` with parent links intact across the pool hand-off.
#[test]
fn sharded_predict_trace_reaches_stage_depth() {
    let data = gp_dataset(&SynthSpec::named("obs-depth", 260, 2), 33);
    let (tr, te) = data.split(0.9, 7);
    // Small blocks so each ~117-point shard factorizes through >= 1
    // compression stage (stage spans exist to find).
    let cfg = MkaConfig { d_core: 12, block_size: 32, n_threads: 2, ..MkaConfig::default() };
    let fleet =
        ShardedGp::fit(&tr, &RbfKernel::new(1.0), 0.1, &cfg, 2, ClusterMethod::KMeans).unwrap();

    let guard = obs::start_request("op.predict");
    let _ = fleet.predict(&te.x);
    let trace = guard.finish();

    let by_id: std::collections::HashMap<u64, &obs::SpanRecord> =
        trace.spans.iter().map(|s| (s.id, s)).collect();
    let depth_of = |s: &obs::SpanRecord| {
        let mut d = 1;
        let mut cur = s;
        while cur.parent != 0 {
            cur = by_id[&cur.parent];
            d += 1;
        }
        d
    };

    let root = trace.spans.iter().find(|s| s.id == 1).expect("root span");
    assert_eq!(root.name, "op.predict");
    for name in ["sharded.predict", "shard ", "gp.predict"] {
        assert!(
            trace.spans.iter().any(|s| s.name.starts_with(name)),
            "no span named {name}* in {:?}",
            trace.spans.iter().map(|s| &s.name).collect::<Vec<_>>()
        );
    }
    let stage = trace
        .spans
        .iter()
        .filter(|s| s.name.starts_with("stage ") && s.name.contains("fwd"))
        .max_by_key(|s| depth_of(s))
        .expect("no cascade stage span recorded");
    assert!(
        depth_of(stage) >= 4,
        "stage span too shallow (depth {}): the pool hand-off lost its parent",
        depth_of(stage)
    );

    // The rendered tree carries self/child wall-time at every node.
    let tree = obs::trace_tree_json(&trace);
    let root_node = tree.get("root").expect("tree root");
    for key in ["wall_us", "self_us", "child_us"] {
        assert!(root_node.num_field(key).is_some(), "tree root missing {key}");
    }
    assert!(tree.num_field("n_spans").unwrap() >= 6.0);
}

/// Both observability rings are hard-bounded: pushing far past capacity
/// never grows them beyond it. (Capacity is a process-global other tests
/// may also set; bound against the max of before/after reads.)
#[test]
fn trace_and_event_rings_stay_bounded() {
    let trace_cap = obs::trace_capacity();
    for i in 0..trace_cap + 5 {
        let g = obs::start_request(&format!("ring-probe-{i}"));
        drop(g);
    }
    let cap_now = obs::trace_capacity().max(trace_cap);
    assert!(
        obs::recent_traces(usize::MAX).len() <= cap_now,
        "trace ring exceeded capacity {cap_now}"
    );

    let log_cap = obs::log_capacity();
    for i in 0..log_cap + 10 {
        obs::log!(Info, "obs.integration", { "i" => i }, "ring bound probe {i}");
    }
    let cap_now = obs::log_capacity().max(log_cap);
    let events = obs::recent_events(obs::Level::Debug, usize::MAX);
    assert!(events.len() <= cap_now, "event ring exceeded capacity {cap_now}");
    assert!(
        events.iter().any(|e| e.target == "obs.integration"),
        "own events displaced entirely from a ring larger than the push count"
    );
}

fn fit_req(model: &str, n: usize, shards: usize) -> Json {
    let data = synth("obs-tcp", n, 1, 3);
    fit_json(model, "mka", &data, 8).with("shards", Json::Num(shards as f64))
}

fn predict_req(model: &str, trace: Option<bool>) -> Json {
    let mut j = common::predict_json(model, &[&[0.1], &[0.9], &[1.7]]);
    if let Some(t) = trace {
        j.set("trace", Json::Bool(t));
    }
    j
}

/// Full smoke over a real socket: server with a Chrome trace-event sink
/// (`trace_out` implies trace-all), sharded fit, traced and untraced
/// predicts with zero value diff, then the three introspection ops —
/// and `diagnose` must not trigger a single new factorization.
#[test]
fn tcp_round_trip_with_trace_out_sink() {
    let sink =
        std::env::temp_dir().join(format!("mka_obs_integration_{}.json", std::process::id()));
    let cfg = ServiceConfig {
        batch_window_ms: 0,
        n_workers: 1,
        trace_out: Some(sink.clone()),
        trace_ring: 16,
        log_ring: 64,
        ..Default::default()
    };
    let (server, mut client, _router) = tcp_rig(cfg);

    let resp = client.call(&fit_req("obs-fleet", 80, 2)).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "fit failed: {resp:?}");

    // trace-all is on (trace_out), so opt *out* explicitly for the
    // baseline; the traced response must match it value-for-value.
    let plain = client.call(&predict_req("obs-fleet", Some(false))).unwrap();
    assert_eq!(plain.get("ok"), Some(&Json::Bool(true)), "{plain:?}");
    assert!(plain.get("trace").is_none(), "trace echoed despite opt-out");
    let traced = client.call(&predict_req("obs-fleet", Some(true))).unwrap();
    assert_eq!(traced.get("ok"), Some(&Json::Bool(true)), "{traced:?}");
    assert_eq!(plain.get("mean"), traced.get("mean"), "tracing changed the mean");
    assert_eq!(plain.get("var"), traced.get("var"), "tracing changed the variance");
    let tree = traced.get("trace").expect("traced predict echoes its span tree");
    assert_eq!(tree.get("root").unwrap().str_field("name"), Some("op.predict"));
    assert!(tree.num_field("n_spans").unwrap() >= 1.0);

    // The ring op replays finished traces.
    let ring = client.call(&Json::parse(r#"{"op":"trace","tail":16}"#).unwrap()).unwrap();
    assert_eq!(ring.get("ok"), Some(&Json::Bool(true)), "{ring:?}");
    assert!(!ring.get("traces").unwrap().as_arr().unwrap().is_empty());
    assert_eq!(ring.num_field("ring_capacity"), Some(16.0));

    let logs = client.call(&Json::parse(r#"{"op":"logs","level":"debug"}"#).unwrap()).unwrap();
    assert_eq!(logs.get("ok"), Some(&Json::Bool(true)), "{logs:?}");
    assert_eq!(logs.str_field("level"), Some("debug"));

    // diagnose: full numerical-health report, zero refactorizations.
    let before = mka_gp::mka::factorize_count();
    let diag =
        client.call(&Json::parse(r#"{"op":"diagnose","model":"obs-fleet"}"#).unwrap()).unwrap();
    assert_eq!(diag.get("ok"), Some(&Json::Bool(true)), "{diag:?}");
    assert_eq!(mka_gp::mka::factorize_count(), before, "diagnose refactorized");
    let d = diag.get("diagnose").unwrap();
    assert_eq!(d.str_field("kind"), Some("sharded"));
    let shards = d.get("shards").unwrap().as_arr().unwrap();
    assert!(shards.len() >= 2, "effective shard count collapsed: {d:?}");
    for s in shards {
        let factor = s.get("model").unwrap().get("factor").unwrap();
        assert!(factor.num_field("condition").unwrap() >= 1.0);
        assert!(factor.num_field("lambda_min").unwrap() > 0.0);
    }

    // Unsupported / unknown targets come back as typed errors.
    let bad = client.call(&Json::parse(r#"{"op":"diagnose","model":"ghost"}"#).unwrap()).unwrap();
    assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));

    drop(client);
    drop(server);
    // Detach the sink (and the implied trace-all) before inspecting the
    // file, so later tests in this process run un-traced.
    obs::clear_trace_out();
    obs::set_trace_all(false);

    let body = std::fs::read_to_string(&sink).unwrap();
    let _ = std::fs::remove_file(&sink);
    assert!(body.starts_with("[\n"), "not a streaming trace-event array");
    assert!(body.contains("\"ph\":\"X\""), "no complete events exported");
    for line in body.lines().skip(1) {
        let line = line.trim_end_matches(',');
        if !line.is_empty() {
            Json::parse(line).unwrap_or_else(|e| panic!("unparseable event line ({e:?}): {line}"));
        }
    }
}
