//! End-to-end proof that a coalesced predict batch runs ONE blocked
//! cascade: b concurrent single-point requests against the same MKA model
//! are merged by the `PredictBatcher` into a single `predict` call, and
//! that call issues exactly one multi-RHS solve (one orthogonal cascade)
//! through the factor stack.
//!
//! This lives in its own integration binary on purpose: the cascade
//! counter is process-wide, and any other test running MKA applies in the
//! same process would pollute the delta.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use mka_gp::coordinator::{Metrics, ModelRegistry, PredictBatcher};
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::gp::mka_gp::MkaGp;
use mka_gp::gp::{GpModel, Prediction};
use mka_gp::kernels::RbfKernel;
use mka_gp::la::Mat;
use mka_gp::mka::{cascade_count, MkaConfig};

/// Wrapper that records the row count of every predict call it serves.
struct Recording {
    inner: MkaGp,
    rows_per_call: Arc<Mutex<Vec<usize>>>,
}

impl GpModel for Recording {
    fn predict(&self, x: &Mat) -> Prediction {
        self.rows_per_call.lock().unwrap().push(x.rows);
        self.inner.predict(x)
    }

    fn name(&self) -> String {
        "recording-mka".into()
    }
}

#[test]
fn coalesced_batch_is_one_blocked_cascade() {
    let data = gp_dataset(&SynthSpec::named("blocked", 120, 2), 3);
    let (tr, te) = data.split(0.9, 1);
    let b = 6.min(te.n());
    assert!(b >= 2, "need at least 2 test points");
    let cfg = MkaConfig { d_core: 16, block_size: 48, n_threads: 1, ..MkaConfig::default() };
    let model = MkaGp::fit(&tr, &RbfKernel::new(1.0), 0.1, &cfg).unwrap();

    let rows_per_call = Arc::new(Mutex::new(Vec::new()));
    let registry = ModelRegistry::new();
    registry.publish(
        "m",
        Arc::new(Recording { inner: model, rows_per_call: Arc::clone(&rows_per_call) }),
    );
    let batcher = PredictBatcher::start(
        registry,
        Arc::new(Metrics::new()),
        Duration::from_millis(200),
        64,
        1024,
    );

    let before = cascade_count();
    // Enqueue b single-point requests inside one batching window
    // (submit is non-blocking), then collect all responses.
    let rxs: Vec<_> = (0..b)
        .map(|i| batcher.submit("m", te.x.block(i, i + 1, 0, te.x.cols)))
        .collect();
    let preds: Vec<Prediction> = rxs
        .into_iter()
        .map(|rx| rx.recv().expect("batcher dropped request").expect("predict failed"))
        .collect();
    let cascades = cascade_count() - before;

    // Every caller got its one-point slice back.
    assert_eq!(preds.len(), b);
    for p in &preds {
        assert_eq!(p.mean.len(), 1);
        assert!(p.mean[0].is_finite() && p.var[0] > 0.0);
    }

    // The b requests were coalesced into one model call carrying all rows…
    let calls = rows_per_call.lock().unwrap().clone();
    assert_eq!(calls.iter().sum::<usize>(), b, "all rows served: {calls:?}");
    assert_eq!(calls.len(), 1, "batch was split into {calls:?}");

    // …and that call ran exactly ONE orthogonal cascade: the p+1
    // right-hand sides of the §4.1 predictor ride a single solve_mat.
    assert_eq!(cascades, 1, "expected one blocked cascade, saw {cascades}");

    // Control: b sequential independent predicts cost b cascades.
    let model = Recording {
        inner: MkaGp::fit(&tr, &RbfKernel::new(1.0), 0.1, &cfg).unwrap(),
        rows_per_call: Arc::new(Mutex::new(Vec::new())),
    };
    let before = cascade_count();
    for i in 0..b {
        let _ = model.predict(&te.x.block(i, i + 1, 0, te.x.cols));
    }
    assert_eq!(
        cascade_count() - before,
        b as u64,
        "per-vector serving should cost one cascade per request"
    );

    // Column-parallel execution still counts ONE logical cascade: a wide
    // batch with n_threads > 1 shards the RHS over workers but must not
    // inflate the serving metric.
    let par_cfg = MkaConfig { n_threads: 4, ..cfg };
    let par_model = MkaGp::fit(&tr, &RbfKernel::new(1.0), 0.1, &par_cfg).unwrap();
    // 20 test points -> 21 RHS columns, over the chunking threshold.
    let wide = data.x.block(0, 20, 0, data.x.cols);
    let before = cascade_count();
    let _ = par_model.predict(&wide);
    assert_eq!(
        cascade_count() - before,
        1,
        "column-sharded predict must count one logical cascade"
    );
}
