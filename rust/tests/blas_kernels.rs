//! Bitwise contract of the packed/register-blocked dense microkernels.
//!
//! The blas rewrite vectorizes over the output column index j, so every
//! output element keeps one serial fused multiply-add chain over the full
//! depth k. That makes the result bit-identical across the Scalar / Avx2 /
//! Avx512 dispatch levels AND identical to the plain `mul_add` reference
//! chain below — which is what these tests pin, over shapes that straddle
//! every panel/register boundary (lane−1, lane, lane+1 for both the 8- and
//! 16-wide panels, plus 1, 3 and a deep 257).

use mka_gp::la::blas::{
    available_levels, gemm_acc, gemm_acc_level, gemm_baseline, gemm_mt, gemm_nt, gemm_nt_level,
    gemm_tn, gemm_tn_level, simd_level, syrk_aat, syrk_aat_level, syrk_ata, syrk_ata_level,
    SimdLevel,
};
use mka_gp::la::Mat;
use mka_gp::util::Rng;

fn randm(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(r, c, |_, _| rng.normal())
}

/// The canonical per-element chain: fold alpha into the left operand with
/// one multiply, then one fused multiply-add per depth step, ascending k,
/// accumulated onto the existing C entry. Every kernel path must reproduce
/// these exact bits.
fn ref_gemm_acc(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) {
    if alpha == 0.0 {
        return;
    }
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut s = 0.0f64;
            for k in 0..a.cols {
                let l = alpha * a.at(i, k);
                s = l.mul_add(b.at(k, j), s);
            }
            let v = c.at(i, j) + s;
            c.set(i, j, v);
        }
    }
}

/// Reference for Aᵀ B (left scalar is the raw A entry — no alpha fold).
fn ref_gemm_tn(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.cols, b.cols);
    for i in 0..a.cols {
        for j in 0..b.cols {
            let mut s = 0.0f64;
            for k in 0..a.rows {
                s = a.at(k, i).mul_add(b.at(k, j), s);
            }
            c.set(i, j, s);
        }
    }
    c
}

/// Reference for A Bᵀ.
fn ref_gemm_nt(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        for j in 0..b.rows {
            let mut s = 0.0f64;
            for k in 0..a.cols {
                s = a.at(i, k).mul_add(b.at(j, k), s);
            }
            c.set(i, j, s);
        }
    }
    c
}

/// Shapes that straddle every panel boundary: 1/3 (degenerate), 7/8/9
/// (Avx2 panel edge), 15/16/17 (Avx512 panel edge), 257 (deep/wide edge).
const DIMS: [usize; 9] = [1, 3, 7, 8, 9, 15, 16, 17, 257];

#[test]
fn gemm_acc_bitwise_matches_reference_all_levels_all_shapes() {
    let levels = available_levels();
    let mut seed = 100;
    for &m in &DIMS {
        for &k in &DIMS {
            for &n in &DIMS {
                // Keep the cube affordable: skip combos with two 257 dims.
                if [m, k, n].iter().filter(|&&d| d == 257).count() > 1 {
                    continue;
                }
                seed += 1;
                let a = randm(m, k, seed);
                let b = randm(k, n, seed + 7000);
                let c0 = randm(m, n, seed + 14_000);
                let mut want = c0.clone();
                ref_gemm_acc(1.3, &a, &b, &mut want);
                for &level in &levels {
                    let mut c = c0.clone();
                    gemm_acc_level(level, 1.3, &a, &b, &mut c);
                    assert_eq!(c.data, want.data, "{level:?} {m}x{k}x{n}");
                }
            }
        }
    }
}

#[test]
fn alpha_zero_is_bitwise_noop_and_negative_alpha_matches() {
    for (m, k, n) in [(4, 5, 8), (8, 8, 8), (3, 257, 9), (9, 16, 17)] {
        let a = randm(m, k, 1);
        let b = randm(k, n, 2);
        let c0 = randm(m, n, 3);
        for &level in &available_levels() {
            let mut c = c0.clone();
            gemm_acc_level(level, 0.0, &a, &b, &mut c);
            assert_eq!(c.data, c0.data, "alpha=0 must not touch C ({level:?})");
            let mut c = c0.clone();
            let mut want = c0.clone();
            gemm_acc_level(level, -0.7, &a, &b, &mut c);
            ref_gemm_acc(-0.7, &a, &b, &mut want);
            assert_eq!(c.data, want.data, "alpha=-0.7 ({level:?}) {m}x{k}x{n}");
        }
    }
}

#[test]
fn repeated_accumulation_onto_same_target_matches() {
    let a = randm(9, 17, 11);
    let b = randm(17, 15, 12);
    let c0 = randm(9, 15, 13);
    let mut want = c0.clone();
    ref_gemm_acc(0.5, &a, &b, &mut want);
    ref_gemm_acc(0.5, &a, &b, &mut want);
    for &level in &available_levels() {
        let mut c = c0.clone();
        gemm_acc_level(level, 0.5, &a, &b, &mut c);
        gemm_acc_level(level, 0.5, &a, &b, &mut c);
        assert_eq!(c.data, want.data, "double accumulate ({level:?})");
    }
}

#[test]
fn shared_operand_gemm_is_supported() {
    // A used as both operands (aliased reads are fine; only C is written).
    let a = randm(17, 17, 21);
    let mut want = Mat::zeros(17, 17);
    ref_gemm_acc(1.0, &a, &a, &mut want);
    let mut c = Mat::zeros(17, 17);
    gemm_acc(1.0, &a, &a, &mut c);
    assert_eq!(c.data, want.data);
}

#[test]
fn tn_nt_bitwise_match_reference_across_levels() {
    for (r, c1, c2) in [(7, 9, 17), (16, 8, 15), (257, 9, 8), (3, 1, 1)] {
        let a = randm(r, c1, 31);
        let b = randm(r, c2, 32);
        let want_tn = ref_gemm_tn(&a, &b);
        let at = randm(c1, r, 33);
        let bt = randm(c2, r, 34);
        let want_nt = ref_gemm_nt(&at, &bt);
        for &level in &available_levels() {
            assert_eq!(gemm_tn_level(level, &a, &b).data, want_tn.data, "tn {level:?}");
            assert_eq!(gemm_nt_level(level, &at, &bt).data, want_nt.data, "nt {level:?}");
        }
    }
}

#[test]
fn syrk_bitwise_equals_its_gemm_twin_across_levels() {
    for (r, c) in [(9, 17), (17, 9), (16, 16), (257, 7)] {
        let a = randm(r, c, 41);
        for &level in &available_levels() {
            let ata = syrk_ata_level(level, &a);
            assert_eq!(ata.data, gemm_tn_level(level, &a, &a).data, "ata {level:?}");
            let aat = syrk_aat_level(level, &a);
            assert_eq!(aat.data, gemm_nt_level(level, &a, &a).data, "aat {level:?}");
        }
    }
}

#[test]
fn threads_and_dispatch_agree_with_reference() {
    // Big enough to clear the banding threshold; odd on every edge.
    let a = randm(131, 97, 51);
    let b = randm(97, 139, 52);
    let mut want = Mat::zeros(131, 139);
    ref_gemm_acc(1.0, &a, &b, &mut want);
    for t in [1, 2, 4] {
        assert_eq!(gemm_mt(&a, &b, t).data, want.data, "threads={t}");
    }
    // The ambient entry points resolve to some available level and still
    // produce the reference bits.
    assert!(available_levels().contains(&simd_level()));
    assert_eq!(gemm_tn(&a, &b).data, ref_gemm_tn(&a, &b).data);
    let bt = randm(139, 97, 53);
    assert_eq!(gemm_nt(&a, &bt).data, ref_gemm_nt(&a, &bt).data);
    assert_eq!(syrk_ata(&a).data, gemm_tn(&a, &a).data);
    assert_eq!(syrk_aat(&a).data, gemm_nt(&a, &a).data);
}

#[test]
fn zero_rows_are_skipped_without_touching_output() {
    // Whole-panel zero skip: rows of A that are entirely zero leave their
    // C rows bitwise untouched even under accumulate with alpha != 1.
    let mut a = randm(12, 33, 61);
    for i in [0, 5, 11] {
        for v in a.row_mut(i) {
            *v = 0.0;
        }
    }
    let b = randm(33, 19, 62);
    let c0 = randm(12, 19, 63);
    let mut want = c0.clone();
    ref_gemm_acc(2.5, &a, &b, &mut want);
    for &level in &available_levels() {
        let mut c = c0.clone();
        gemm_acc_level(level, 2.5, &a, &b, &mut c);
        assert_eq!(c.data, want.data, "{level:?}");
        for i in [0usize, 5, 11] {
            assert_eq!(c.row(i), c0.row(i), "zero row {i} must be untouched");
        }
    }
}

#[test]
fn scalar_level_is_always_available_and_forced_scalar_respects_env() {
    let levels = available_levels();
    assert!(levels.contains(&SimdLevel::Scalar));
    // When CI forces the scalar fallback, the ambient dispatch must obey.
    if std::env::var("MKA_FORCE_SCALAR").map(|v| !v.is_empty() && v != "0").unwrap_or(false) {
        assert_eq!(simd_level(), SimdLevel::Scalar);
    }
}

#[test]
fn baseline_kernel_still_matches_new_kernels_numerically() {
    // The retained pre-rewrite kernel (bench yardstick) differs in
    // summation order, so compare with a tolerance, not bits.
    let a = randm(64, 48, 71);
    let b = randm(48, 72, 72);
    let new = gemm_mt(&a, &b, 1);
    let old = gemm_baseline(&a, &b);
    let mut worst = 0.0f64;
    for (x, y) in new.data.iter().zip(&old.data) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst < 1e-10, "baseline drift {worst}");
}
