//! Coordinator demo: start the GP service in-process, then act as a
//! client — async-fit two models (MKA + SoR), poll the job queue, run
//! batched predictions from several concurrent client threads, and dump
//! service metrics.
//!
//!     cargo run --release --example gp_server

use std::sync::Arc;

use mka_gp::coordinator::{Client, Router, Server, ServiceConfig};
use mka_gp::prelude::*;

fn fit_request(model: &str, method: &str, data: &Dataset, k: usize) -> Json {
    let x: Vec<Json> = (0..data.n()).map(|i| Json::from_f64_slice(data.x.row(i))).collect();
    Json::obj()
        .with("op", Json::Str("fit".into()))
        .with("model", Json::Str(model.into()))
        .with("method", Json::Str(method.into()))
        .with("x", Json::Arr(x))
        .with("y", Json::from_f64_slice(&data.y))
        .with(
            "params",
            Json::obj()
                .with("lengthscale", Json::Num(0.9))
                .with("sigma2", Json::Num(0.1))
                .with("k", Json::Num(k as f64)),
        )
        .with("async", Json::Bool(true))
}

fn main() -> Result<()> {
    // --- boot the service -------------------------------------------------
    let cfg = ServiceConfig { port: 0, n_workers: 2, batch_window_ms: 4, ..Default::default() };
    let router = Arc::new(Router::new(cfg));
    let server = Server::start(Arc::clone(&router), "127.0.0.1", 0)?;
    let addr = format!("{}", server.addr());
    println!("coordinator listening on {addr}");

    // --- client: async fits ------------------------------------------------
    let data = synth::gp_dataset(&SynthSpec::named("served", 400, 4), 3);
    let (train, test) = data.split(0.9, 1);
    let mut client = Client::connect(&addr)?;
    let mut jobs = Vec::new();
    for (name, method) in [("gp-mka", "mka"), ("gp-sor", "sor")] {
        let resp = client.call(&fit_request(name, method, &train, 24))?;
        let job = resp.usize_field("job_id").expect("job id");
        println!("submitted fit {name} (method {method}) -> job {job}");
        jobs.push(job);
    }

    // --- poll the job queue -------------------------------------------------
    for job in jobs {
        loop {
            let resp = client.call(
                &Json::obj().with("op", Json::Str("job".into())).with("job_id", Json::Num(job as f64)),
            )?;
            let state = resp.str_field("state").unwrap_or("?").to_string();
            if state == "done" {
                println!(
                    "job {job} done in {:.3}s",
                    resp.num_field("fit_secs").unwrap_or(f64::NAN)
                );
                break;
            }
            if state == "failed" {
                println!("job {job} FAILED: {:?}", resp.str_field("error"));
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    }

    // --- concurrent batched predictions -------------------------------------
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let addr = addr.clone();
            let test = test.clone();
            std::thread::spawn(move || -> Result<f64> {
                let mut c = Client::connect(&addr)?;
                let lo = t * test.n() / 4;
                let hi = (t + 1) * test.n() / 4;
                let x: Vec<Json> =
                    (lo..hi).map(|i| Json::from_f64_slice(test.x.row(i))).collect();
                let req = Json::obj()
                    .with("op", Json::Str("predict".into()))
                    .with("model", Json::Str("gp-mka".into()))
                    .with("x", Json::Arr(x));
                let resp = c.call(&req)?;
                let mean = resp.get("mean").unwrap().f64_array().unwrap();
                Ok(smse(&test.y[lo..hi], &mean))
            })
        })
        .collect();
    for (t, h) in handles.into_iter().enumerate() {
        println!("client {t}: shard SMSE = {:.4}", h.join().unwrap()?);
    }

    // --- metrics -------------------------------------------------------------
    let m = client.call(&Json::obj().with("op", Json::Str("metrics".into())))?;
    println!("\nservice metrics:\n{}", m.dump_pretty());
    Ok(())
}
