use mka_gp::la::{Mat, SymEig};
use mka_gp::util::{Rng, Timer};
fn main() {
    let mut rng = Rng::new(1);
    for n in [256usize, 512, 1024] {
        let mut a = Mat::from_fn(n, n, |_, _| rng.normal());
        a.symmetrize();
        let t = Timer::start();
        let e = SymEig::new(&a);
        println!("tql2 n={n}: {:.3}s (max|recon-a| check skipped, λmax={:.2})", t.elapsed_secs(), e.values.last().unwrap());
    }
}
