//! END-TO-END DRIVER (recorded in EXPERIMENTS.md): exercises every layer
//! of the stack on a real small workload —
//!
//!   1. loads the AOT XLA/Pallas artifacts through PJRT (Layer 1+2) and
//!      builds the kernel matrix through the tiled engine,
//!   2. runs the full MKA pipeline (clustering → MMF core-diagonal
//!      compression → telescoping factor → direct solve) on a Table-1-size
//!      dataset (Layer 3),
//!   3. serves batched prediction requests through the coordinator over
//!      TCP, reporting latency/throughput,
//!   4. reports SMSE/MNLP against Full GP and SoR at the paper's budget.
//!
//!     cargo run --release --example regression_suite [-- --n 2066 --k 16]

use std::sync::Arc;

use mka_gp::baselines::Sor;
use mka_gp::coordinator::{Client, Router, Server, ServiceConfig};
use mka_gp::gp::GpModel;
use mka_gp::kernels::gram::GramBuilder;
use mka_gp::la::stats::quantile_sorted;
use mka_gp::prelude::*;
use mka_gp::runtime::engine::XlaEngine;
use mka_gp::util::Timer;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let n = args.get_usize("n", 2066); // rupture-size by default
    let k = args.get_usize("k", 16);
    let seed = args.get_u64("seed", 42);

    println!("=== mka-gp end-to-end regression suite ===");
    println!("workload: n={n}, k(d_core)={k}");

    // ------------------------------------------------------------------
    // 1. AOT artifacts through PJRT (falls back to native with a warning).
    // ------------------------------------------------------------------
    let engine = match XlaEngine::start(&mka_gp::runtime::default_artifacts_dir()) {
        Ok(e) => {
            println!("[L1/L2] XLA engine up: gram tile {0}x{0}", e.manifest().gram_tile);
            Some(e)
        }
        Err(e) => {
            println!("[L1/L2] engine unavailable ({e}); native fallback");
            None
        }
    };

    // Broad-spectrum dataset at rupture's (n, d).
    let spec = SynthSpec { ell_local: 0.4, local_weight: 0.5, ..SynthSpec::named("e2e", n, 8) };
    let data = synth::gp_dataset(&spec, seed);
    let (train, test) = data.split(0.9, 1);

    let ell = 0.7;
    let sigma2 = 0.1;

    // Kernel matrix through the AOT tile engine (the O(n²) hot spot).
    let t = Timer::start();
    let builder = GramBuilder::rbf(
        ell,
        1.0,
        engine.as_ref().map(|e| Arc::new(e.handle()) as Arc<dyn mka_gp::kernels::gram::TileEngine>),
    );
    let mut kmat = builder.build_sym(&train.x);
    let gram_s = t.elapsed_secs();
    println!(
        "[L2] K ({}x{}) assembled in {:.2}s via {}",
        kmat.rows,
        kmat.cols,
        gram_s,
        if builder.has_engine() { "AOT XLA tiles" } else { "native kernels" }
    );

    // ------------------------------------------------------------------
    // 2. MKA factorization + direct operator algebra.
    // ------------------------------------------------------------------
    kmat.add_diag(sigma2);
    let cfg = MkaConfig { d_core: k, block_size: 128, ..MkaConfig::default() };
    let t = Timer::start();
    let factor = mka_gp::mka::factorize(&kmat, Some(&train.x), &cfg)?;
    let fact_s = t.elapsed_secs();
    println!(
        "[L3] MKA factorized in {:.2}s: {} stages, d_core {}, {} stored reals ({}x compression)",
        fact_s,
        factor.n_stages(),
        factor.d_core(),
        factor.stored_reals(),
        (kmat.rows * kmat.cols) / factor.stored_reals().max(1)
    );
    let t = Timer::start();
    let alpha = factor.solve(&train.y)?;
    println!("[L3] direct solve K̃⁻¹y in {:.4}s (‖α‖={:.2})", t.elapsed_secs(),
        alpha.iter().map(|a| a * a).sum::<f64>().sqrt());
    println!("[L3] logdet = {:.1}", factor.logdet()?);

    // ------------------------------------------------------------------
    // 3. Serve through the coordinator; batched predictions over TCP.
    // ------------------------------------------------------------------
    let svc = ServiceConfig { port: 0, n_workers: 2, batch_window_ms: 3, ..Default::default() };
    let router = Arc::new(Router::new(svc));
    let kern = RbfKernel::new(ell);
    let model = MkaGp::fit(&train, &kern, sigma2, &cfg)?;
    router.registry.publish("e2e", Arc::new(model));
    let server = Server::start(Arc::clone(&router), "127.0.0.1", 0)?;
    let addr = format!("{}", server.addr());
    println!("[L3] coordinator on {addr}, model 'e2e' published");

    // Latency measurement: sequential single-batch requests.
    let mut client = Client::connect(&addr)?;
    let shard = 32.min(test.n());
    let mut lats = Vec::new();
    let t_all = Timer::start();
    let mut preds: Vec<f64> = Vec::new();
    let mut vars: Vec<f64> = Vec::new();
    let mut idx = 0;
    while idx < test.n() {
        let hi = (idx + shard).min(test.n());
        let x: Vec<Json> = (idx..hi).map(|i| Json::from_f64_slice(test.x.row(i))).collect();
        let req = Json::obj()
            .with("op", Json::Str("predict".into()))
            .with("model", Json::Str("e2e".into()))
            .with("x", Json::Arr(x));
        let t = Timer::start();
        let resp = client.call(&req)?;
        lats.push(t.elapsed_secs());
        if resp.get("ok") != Some(&Json::Bool(true)) {
            return Err(Error::Coordinator(format!("predict failed: {resp:?}")));
        }
        preds.extend(resp.get("mean").unwrap().f64_array().unwrap());
        vars.extend(resp.get("var").unwrap().f64_array().unwrap());
        idx = hi;
    }
    let wall = t_all.elapsed_secs();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "[serve] {} points in {:.2}s  ({:.1} pts/s) | batch latency p50={:.1}ms p95={:.1}ms",
        test.n(),
        wall,
        test.n() as f64 / wall,
        quantile_sorted(&lats, 0.5) * 1e3,
        quantile_sorted(&lats, 0.95) * 1e3,
    );

    // ------------------------------------------------------------------
    // 4. Accuracy vs Full GP and SoR.
    // ------------------------------------------------------------------
    let e_mka = smse(&test.y, &preds);
    let nl_mka = mnlp(&test.y, &preds, &vars);
    println!("\n{:<10} {:>8} {:>8} {:>10}", "method", "SMSE", "MNLP", "fit(s)");
    println!("{:<10} {:>8.4} {:>8.4} {:>10.2}", "MKA", e_mka, nl_mka, fact_s);
    let t = Timer::start();
    let sor = Sor::fit(&train, &kern, sigma2, k, seed)?;
    let sor_fit = t.elapsed_secs();
    let ps = sor.predict(&test.x);
    println!(
        "{:<10} {:>8.4} {:>8.4} {:>10.2}",
        "SOR",
        smse(&test.y, &ps.mean),
        mnlp(&test.y, &ps.mean, &ps.var),
        sor_fit
    );
    if train.n() <= 3000 {
        let t = Timer::start();
        let full = FullGp::fit(&train, &kern, sigma2)?;
        let full_fit = t.elapsed_secs();
        let pf = full.predict(&test.x);
        println!(
            "{:<10} {:>8.4} {:>8.4} {:>10.2}",
            "Full",
            smse(&test.y, &pf.mean),
            mnlp(&test.y, &pf.mean, &pf.var),
            full_fit
        );
    }
    println!("\nend-to-end suite complete: all three layers exercised.");
    Ok(())
}
