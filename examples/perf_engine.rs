//! Perf-pass driver for the L1/L2 AOT path: gram-tile and AᵀA throughput
//! through PJRT vs the native Rust kernels.
use mka_gp::kernels::gram::rbf_tile_native;
use mka_gp::la::{syrk_ata, Mat};
use mka_gp::runtime::engine::XlaEngine;
use mka_gp::util::{Rng, Timer};

fn main() {
    let engine = XlaEngine::start(std::path::Path::new("artifacts")).expect("artifacts");
    let h = engine.handle();
    let mut rng = Rng::new(1);
    let t_sz = h.gram_tile_size();
    let d = h.gram_max_dim();
    let x = Mat::from_fn(t_sz, d, |_, _| rng.normal());
    let y = Mat::from_fn(t_sz, d, |_, _| rng.normal());
    let reps = 50;
    let t = Timer::start();
    for _ in 0..reps { std::hint::black_box(h.rbf_tile(&x, &y, 1.0, 1.0).unwrap()); }
    let xla_s = t.elapsed_secs() / reps as f64;
    let t = Timer::start();
    for _ in 0..reps { std::hint::black_box(rbf_tile_native(&x, &y, 1.0, 1.0)); }
    let nat_s = t.elapsed_secs() / reps as f64;
    let flops = (t_sz * t_sz * (2 * d + 8)) as f64;
    println!("gram tile {t_sz}x{t_sz}x{d}: xla {:.1}us ({:.2} GF/s) | native {:.1}us ({:.2} GF/s)",
        xla_s * 1e6, flops / xla_s / 1e9, nat_s * 1e6, flops / nat_s / 1e9);

    let m = 256;
    let a = Mat::from_fn(m, m, |_, _| rng.normal());
    let t = Timer::start();
    for _ in 0..20 { std::hint::black_box(h.ata(&a).unwrap()); }
    let xla_s = t.elapsed_secs() / 20.0;
    let t = Timer::start();
    for _ in 0..20 { std::hint::black_box(syrk_ata(&a)); }
    let nat_s = t.elapsed_secs() / 20.0;
    let flops = (m * m * m) as f64;
    println!("ata {m}: xla {:.2}ms ({:.2} GF/s) | native {:.2}ms ({:.2} GF/s)",
        xla_s * 1e3, flops / xla_s / 1e9, nat_s * 1e3, flops / nat_s / 1e9);
}
