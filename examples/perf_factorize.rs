//! Perf-pass driver: factorize + matvec/solve on a mid-size kernel matrix.
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::kernels::{Kernel, RbfKernel};
use mka_gp::mka::{factorize, MkaConfig};
use mka_gp::util::{Args, Rng, Timer};

fn main() {
    let args = Args::from_env(false);
    let n = args.get_usize("n", 2048);
    let reps = args.get_usize("reps", 3);
    let data = gp_dataset(&SynthSpec::named("perf", n, 4), 5);
    let t = Timer::start();
    let mut k = RbfKernel::new(0.8).gram_sym(&data.x);
    k.add_diag(0.1);
    println!("gram: {:.2}s", t.elapsed_secs());
    let cfg = MkaConfig { d_core: 64, block_size: 256, ..MkaConfig::default() };
    let mut f = None;
    for _ in 0..reps {
        let t = Timer::start();
        f = Some(factorize(&k, Some(&data.x), &cfg).unwrap());
        println!("factorize: {:.3}s", t.elapsed_secs());
    }
    let f = f.unwrap();
    let mut rng = Rng::new(1);
    let z = rng.normal_vec(n);
    let t = Timer::start();
    for _ in 0..2000 { std::hint::black_box(f.matvec(&z)); }
    println!("matvec x2000: {:.3}s", t.elapsed_secs());
    let t = Timer::start();
    for _ in 0..2000 { std::hint::black_box(f.solve(&z).unwrap()); }
    println!("solve  x2000: {:.3}s", t.elapsed_secs());
}
