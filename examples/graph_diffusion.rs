//! §4 extension: diffusion kernels on sparse graphs without ever writing
//! down the dense kernel matrix. MKA factorizes the graph Laplacian once;
//! Proposition 7 then gives exp(−βL̃)·v (and determinants, powers, …) in
//! O(n + d³) per application — compare against the dense O(n³) EVD oracle.
//!
//!     cargo run --release --example graph_diffusion [-- --n 1500]

use mka_gp::kernels::graph::{diffusion_dense, knn_graph, random_graph};
use mka_gp::la::gemv;
use mka_gp::prelude::*;
use mka_gp::util::Timer;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let n = args.get_usize("n", 1200);
    let beta = args.get_f64("beta", 0.6);
    let mut rng = Rng::new(11);

    println!("=== diffusion kernels via MKA (paper §4) ===");

    // --- a sparse kNN graph over clustered points -------------------------
    let x = mka_gp::data::synth::clustered_features(n, 3, 6, &mut rng);
    let g = knn_graph(&x, 6, 1.0);
    let lap = g.laplacian();
    println!("kNN graph: n={n}, nnz(L)={} ({:.2}% dense)", lap.nnz(),
        100.0 * lap.nnz() as f64 / (n * n) as f64);

    // --- factorize L (dense view of the sparse Laplacian) -----------------
    let cfg = MkaConfig { d_core: 64, block_size: 128, ..MkaConfig::default() };
    let ldense = lap.to_dense();
    let t = Timer::start();
    let factor = mka_gp::mka::factorize(&ldense, Some(&x), &cfg)?;
    println!("MKA(L) in {:.2}s: {} stages, {} stored reals", t.elapsed_secs(),
        factor.n_stages(), factor.stored_reals());

    // --- diffusion semantics: exp(−βL)·heat-source -------------------------
    let mut v = vec![0.0; n];
    v[0] = 1.0;
    let t = Timer::start();
    let heat = factor.exp_apply(-beta, &v);
    let fast_s = t.elapsed_secs();
    // heat stays a probability-like distribution: mass conserved
    let mass: f64 = heat.iter().sum();
    println!("exp(−βL̃)·e0 in {:.4}s; heat mass Σ = {:.4} (exact 1; drift measures truncation of the point source — smooth inputs fare far better, see below)", fast_s, mass);

    // --- compare against the dense oracle at a modest size -----------------
    let n_small = 400.min(n);
    let gs = random_graph(n_small, 5.0, &mut rng);
    let lsd = gs.laplacian().to_dense();
    let t = Timer::start();
    let exact = diffusion_dense(&gs, beta);
    let dense_s = t.elapsed_secs();
    let factor_s = mka_gp::mka::factorize(&lsd, None, &cfg)?;
    let mut v = vec![0.0; n_small];
    v[n_small / 2] = 1.0;
    let t = Timer::start();
    let approx = factor_s.exp_apply(-beta, &v);
    let mka_s = t.elapsed_secs();
    let exact_v = gemv(&exact, &v);
    let err = approx
        .iter()
        .zip(&exact_v)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let scale = exact_v.iter().fold(0.0f64, |m, x| m.max(x.abs()));
    println!(
        "n={n_small}: dense EVD {:.2}s vs MKA apply {:.5}s; max abs err {:.2e} (scale {:.2e})",
        dense_s, mka_s, err, scale
    );

    // --- determinant of the regularized Laplacian --------------------------
    let mut lreg = lsd.clone();
    lreg.add_diag(0.5);
    let f = mka_gp::mka::factorize(&lreg, None, &cfg)?;
    println!("logdet(L + 0.5I) via Prop. 7: {:.2}", f.logdet()?);
    println!("done.");
    Ok(())
}
