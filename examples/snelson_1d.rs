//! Figure 1 reproduction driver: the Snelson-style 1D toy. Fits all six
//! methods and writes per-method CSV curves (grid, mean, ±1σ) plus the
//! training data to `results/fig1/`, and prints each method's deviation
//! from the Full GP — the quantitative version of "MKA fits the data
//! almost as well as the Full GP does".
//!
//!     cargo run --release --example snelson_1d [-- --n 200 --k 10]

use mka_gp::data::loader::write_table;
use mka_gp::experiments::methods::Method;
use mka_gp::experiments::snelson;
use mka_gp::gp::cv::HyperParams;
use mka_gp::prelude::*;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let n = args.get_usize("n", 200);
    let k = args.get_usize("k", 10); // paper: 10 pseudo-inputs
    let seed = args.get_u64("seed", 7);
    // Paper protocol: ground truth from a GP with ℓ = 0.5.
    let hp = HyperParams { lengthscale: 0.5, sigma2: 0.01 };

    println!("Snelson 1D: n={n}, pseudo-inputs/d_core={k}");
    let (data, curves) = snelson::run(n, k, 220, hp, &Method::ALL, seed);

    let out_dir = std::path::Path::new("results/fig1");
    // training data
    let rows: Vec<Vec<f64>> = (0..data.n()).map(|i| vec![data.x.at(i, 0), data.y[i]]).collect();
    write_table(&out_dir.join("data.csv"), &["x", "y"], &rows)?;
    // per-method curves
    for c in &curves {
        let rows: Vec<Vec<f64>> = c
            .grid
            .iter()
            .zip(&c.mean)
            .zip(&c.std)
            .map(|((x, m), s)| vec![*x, *m, m - s, m + s])
            .collect();
        let path = out_dir.join(format!("{}.csv", c.method.label().to_lowercase()));
        write_table(&path, &["x", "mean", "lo", "hi"], &rows)?;
        println!("wrote {}", path.display());
    }

    println!("\nmean |deviation from Full GP| over the grid:");
    let mut devs = snelson::deviation_from_full(&curves);
    devs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (m, d) in &devs {
        println!("  {:<6} {:.4}", m.label(), d);
    }
    if let Some((best, _)) = devs.first() {
        println!("\nclosest to Full: {} (the paper's Figure 1 shows MKA here)", best.label());
    }
    Ok(())
}
