//! Quickstart: fit MKA-GP on a synthetic broad-spectrum dataset and compare
//! against the exact GP and the SoR (Nyström) baseline at the same budget.
//!
//!     cargo run --release --example quickstart

use mka_gp::baselines::Sor;
use mka_gp::gp::GpModel;
use mka_gp::prelude::*;

fn main() -> Result<()> {
    // 1. Data: 800 points, 3-D, mixed long+short length scales (the regime
    //    the paper targets — low-rank methods can't capture the local part).
    let spec = SynthSpec {
        ell_local: 0.4,
        local_weight: 0.55,
        ..SynthSpec::named("quickstart", 800, 3)
    };
    let data = synth::gp_dataset(&spec, 42);
    let (train, test) = data.split(0.9, 1);
    println!("dataset: n={} d={} ({} train / {} test)", data.n(), data.dim(), train.n(), test.n());

    // 2. Kernel + budget: d_core = #pseudo-inputs = 32.
    let kernel = RbfKernel::new(0.5);
    let sigma2 = 0.1;
    let k = 32;

    // 3. Models.
    let full = FullGp::fit(&train, &kernel, sigma2)?;
    let sor = Sor::fit(&train, &kernel, sigma2, k, 7)?;
    let mka_cfg = MkaConfig { d_core: k, block_size: 128, ..MkaConfig::default() };
    let mka = MkaGp::fit(&train, &kernel, sigma2, &mka_cfg)?;

    // 4. Evaluate.
    println!("\n{:<10} {:>8} {:>8}", "method", "SMSE", "MNLP");
    for model in [&full as &dyn GpModel, &sor, &mka] {
        let pred = model.predict(&test.x);
        let e = smse(&test.y, &pred.mean);
        let nl = mnlp(&test.y, &pred.mean, &pred.var);
        println!("{:<10} {:>8.4} {:>8.4}", model.name(), e, nl);
    }

    // 5. The factorization is a direct method: inverse, logdet, powers come
    //    for free (Proposition 7).
    let mut kmat = kernel.gram_sym(&train.x);
    kmat.add_diag(sigma2);
    let factor = mka_gp::mka::factorize(&kmat, Some(&train.x), &mka_cfg)?;
    println!(
        "\nMKA factor: {} stages, d_core={}, stored reals {} (dense would be {})",
        factor.n_stages(),
        factor.d_core(),
        factor.stored_reals(),
        train.n() * train.n()
    );
    println!("logdet(K+σ²I) = {:.2}", factor.logdet()?);
    Ok(())
}
